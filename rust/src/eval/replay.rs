//! Offline replay: apply any exit policy to a recorded trace and compute
//! the (tokens used, expected accuracy) outcome — the engine behind every
//! threshold-sweep figure.

use crate::exit::{ExitDecision, ExitPolicy, ExitReason, LineObs};
use crate::monitor::Trace;
use crate::util::json::JsonScanner;

/// Which recorded entropy stream feeds the policy (models x prefix
/// variants of the paper's ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    /// Main model, prefix string appended (Eq. 13) — the headline EAT.
    MainPrefixed,
    /// Main model, bare `</think>` (Eq. 12).
    MainPlain,
    /// Proxy model, prefix string (black-box setting).
    Proxy,
    /// Entropy after newline (Eq. 14) — App. F's negative control.
    Newline,
}

impl Signal {
    pub fn extract(&self, p: &crate::monitor::LinePoint) -> Option<f64> {
        match self {
            Signal::MainPrefixed => Some(p.eat),
            Signal::MainPlain => p.eat_plain,
            Signal::Proxy => p.eat_proxy,
            Signal::Newline => p.eat_newline,
        }
    }
}

/// Outcome of replaying one policy over one trace.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Line at which the policy exited (None = consumed the whole trace).
    pub exit_line: Option<usize>,
    pub exit_reason: ExitReason,
    /// Reasoning tokens actually spent.
    pub reasoning_tokens: usize,
    /// Extra tokens charged for signal evaluation (probes / rollouts).
    pub overhead_tokens: usize,
    /// Expected accuracy at the exit point: Pass@1(Avg@K) (Eq. 9).
    pub accuracy: f64,
    /// Analytic accuracy (exact probability of the correct answer).
    pub accuracy_exact: f64,
}

/// Cost model for signal evaluation, in tokens per evaluation — the
/// paper's accounting in Figs. 6b/21: an EAT probe costs suffix_len
/// decode-equivalents; a #UA@K evaluation costs K rollouts of
/// (suffix + answer + EOS); confidence costs one (suffix + 5) rollout.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub probe_suffix_tokens: usize,
    pub answer_tokens: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            probe_suffix_tokens: 3, // </think> Final: A
            answer_tokens: 2,       // value + EOS
        }
    }
}

impl CostModel {
    pub fn eat_eval(&self) -> usize {
        // one forward pass over the suffix = suffix_len token-equivalents
        self.probe_suffix_tokens
    }

    pub fn ua_eval(&self, k: usize) -> usize {
        k * (self.probe_suffix_tokens + self.answer_tokens)
    }

    pub fn confidence_eval(&self) -> usize {
        self.probe_suffix_tokens + 5
    }
}

/// Replay `policy` over `trace`, feeding it the chosen signal stream.
/// `charge_overhead` adds the signal-evaluation token cost to the outcome
/// (Fig. 21 curves charge it; Fig. 3 reports raw reasoning tokens like the
/// paper's main plots).
pub fn replay(
    trace: &Trace,
    policy: &mut dyn ExitPolicy,
    signal: Signal,
    charge_overhead: bool,
) -> ReplayOutcome {
    policy.reset();
    let needs = policy.needs();
    let cost = CostModel::default();
    let mut overhead = 0usize;

    for (i, p) in trace.points.iter().enumerate() {
        let mut obs = LineObs {
            tokens: p.tokens,
            ..Default::default()
        };
        if needs.eat {
            obs.eat = signal.extract(p);
            if obs.eat.is_none() {
                // signal not recorded in this trace; treat as no-exit
                obs.eat = Some(f64::NAN);
            }
            overhead += cost.eat_eval();
        }
        if needs.rollouts_k > 0 {
            obs.unique_answers = Some(p.unique_answers.min(needs.rollouts_k));
            // strided policies only roll out (and pay) every k-th line
            if (i + 1) % needs.rollout_every == 0 {
                overhead += cost.ua_eval(needs.rollouts_k);
            }
        }
        if needs.confidence {
            // same contract as `eat`: a trace recorded without the
            // confidence stream replays as NaN (no adaptive exit), so a
            // confidence policy falls through to its token backstop
            // instead of panicking on the missing signal
            obs.confidence = Some(p.confidence.unwrap_or(f64::NAN));
            overhead += cost.confidence_eval();
        }
        if let ExitDecision::Exit(reason) = policy.observe(&obs) {
            return ReplayOutcome {
                exit_line: Some(p.line),
                exit_reason: reason,
                reasoning_tokens: p.tokens,
                overhead_tokens: if charge_overhead { overhead } else { 0 },
                accuracy: p.pass1_avgk,
                accuracy_exact: p.p_correct,
            };
        }
        let _ = i;
    }

    // ran through the whole recorded trace: the model either terminated by
    // itself or hit the generation budget; outcome is the final point's.
    let last = trace.points.last();
    ReplayOutcome {
        exit_line: None,
        exit_reason: if trace.self_terminated {
            ExitReason::SelfTerminated
        } else {
            ExitReason::TokenBudget
        },
        reasoning_tokens: trace.reasoning_tokens.len(),
        overhead_tokens: if charge_overhead { overhead } else { 0 },
        accuracy: last.map(|p| p.pass1_avgk).unwrap_or(0.0),
        accuracy_exact: last.map(|p| p.p_correct).unwrap_or(0.0),
    }
}

/// `from_json`-compatible numeric read for a point field: the key must
/// exist, a non-numeric value decays to 0.0.
fn req_point_num(p: &JsonScanner, key: &str) -> anyhow::Result<f64> {
    Ok(p.path(&[key])
        .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`"))?
        .path_num(&[])
        .unwrap_or(0.0))
}

/// Lazy twin of [`replay`]: runs the policy straight off JSON text via
/// [`JsonScanner`], reading only the 2–4 fields per line the policy
/// actually needs instead of materializing an 11-field `Trace` first.
/// On well-formed trace JSON it is exactly equivalent to
/// `replay(&Trace::from_scanner(..)?, ..)` — pinned by the differential
/// in `tests/proptests.rs` and the unit test below.
pub fn replay_scanned(
    trace: &JsonScanner,
    policy: &mut dyn ExitPolicy,
    signal: Signal,
    charge_overhead: bool,
) -> anyhow::Result<ReplayOutcome> {
    policy.reset();
    let needs = policy.needs();
    let cost = CostModel::default();
    let mut overhead = 0usize;

    let points = trace
        .path(&["points"])
        .ok_or_else(|| anyhow::anyhow!("missing JSON key `points`"))?;
    let mut last = None;
    for (i, p) in points.array_items().enumerate() {
        let mut obs = LineObs {
            tokens: p.req_usize("tokens")?,
            ..Default::default()
        };
        if needs.eat {
            obs.eat = match signal {
                // `eat` is a required key in the trace schema; missing
                // optional streams replay as NaN (no-exit), like `replay`.
                Signal::MainPrefixed => Some(req_point_num(&p, "eat")?),
                Signal::MainPlain => p.path_num(&["eat_plain"]),
                Signal::Proxy => p.path_num(&["eat_proxy"]),
                Signal::Newline => p.path_num(&["eat_newline"]),
            };
            if obs.eat.is_none() {
                obs.eat = Some(f64::NAN);
            }
            overhead += cost.eat_eval();
        }
        if needs.rollouts_k > 0 {
            obs.unique_answers =
                Some(p.req_usize("unique_answers")?.min(needs.rollouts_k));
            if (i + 1) % needs.rollout_every == 0 {
                overhead += cost.ua_eval(needs.rollouts_k);
            }
        }
        if needs.confidence {
            // missing stream → NaN (no-exit), mirroring `replay`
            obs.confidence = Some(p.path_num(&["confidence"]).unwrap_or(f64::NAN));
            overhead += cost.confidence_eval();
        }
        if let ExitDecision::Exit(reason) = policy.observe(&obs) {
            return Ok(ReplayOutcome {
                exit_line: Some(p.req_usize("line")?),
                exit_reason: reason,
                reasoning_tokens: obs.tokens,
                overhead_tokens: if charge_overhead { overhead } else { 0 },
                accuracy: req_point_num(&p, "pass1_avgk")?,
                accuracy_exact: req_point_num(&p, "p_correct")?,
            });
        }
        last = Some(p);
    }

    let (accuracy, accuracy_exact) = match &last {
        Some(p) => (
            req_point_num(p, "pass1_avgk")?,
            req_point_num(p, "p_correct")?,
        ),
        None => (0.0, 0.0),
    };
    Ok(ReplayOutcome {
        exit_line: None,
        exit_reason: if trace.path_bool(&["self_terminated"]).unwrap_or(false)
        {
            ExitReason::SelfTerminated
        } else {
            ExitReason::TokenBudget
        },
        // `from_json` drops non-numeric reasoning tokens, so count only
        // the items that would survive it.
        reasoning_tokens: trace
            .path(&["reasoning_tokens"])
            .map(|r| {
                r.array_items()
                    .filter(|t| t.path_num(&[]).is_some())
                    .count()
            })
            .unwrap_or(0),
        overhead_tokens: if charge_overhead { overhead } else { 0 },
        accuracy,
        accuracy_exact,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exit::{EatPolicy, TokenBudgetPolicy, UniqueAnswersPolicy};
    use crate::monitor::LinePoint;

    /// A trace whose EAT stabilizes from line 5 and Pass@1 saturates there.
    fn synthetic_trace(n_lines: usize, stabilize_at: usize) -> Trace {
        let points = (1..=n_lines)
            .map(|i| {
                let stable = i >= stabilize_at;
                LinePoint {
                    line: i,
                    tokens: i * 3,
                    eat: if stable { 0.05 } else { 2.5 + ((i % 2) as f64) },
                    eat_proxy: Some(if stable { 0.1 } else { 2.5 }),
                    eat_plain: Some(0.01),
                    eat_newline: Some(1.0),
                    vhat: f64::INFINITY,
                    p_correct: if stable { 0.99 } else { 0.05 },
                    pass1_avgk: if stable { 1.0 } else { 0.06 },
                    unique_answers: if stable { 1 } else { 12 },
                    confidence: Some(if stable { 0.95 } else { 0.3 }),
                }
            })
            .collect();
        Trace {
            question_id: 0,
            n_ops: 5,
            answer: Some(7),
            prompt_tokens: 8,
            self_terminated: false,
            reasoning_tokens: vec![0; n_lines * 3],
            points,
        }
    }

    #[test]
    fn eat_exits_after_stabilization_with_high_accuracy() {
        // with alpha=0.2 the post-transition variance spike decays at
        // ~0.8/line, so a practical delta (0.05) exits ~20 lines after
        // stabilization on a noisy start
        let t = synthetic_trace(30, 5);
        let mut p = EatPolicy::new(0.2, 0.05, 10_000);
        let out = replay(&t, &mut p, Signal::MainPrefixed, false);
        let line = out.exit_line.expect("should exit");
        assert!(line > 5 && line < 30, "line={line}");
        assert!(out.accuracy > 0.9);
        assert!(out.reasoning_tokens < 90);
    }

    #[test]
    fn token_budget_cuts_at_t() {
        let t = synthetic_trace(30, 5);
        let mut p = TokenBudgetPolicy::new(9);
        let out = replay(&t, &mut p, Signal::MainPrefixed, false);
        assert_eq!(out.exit_line, Some(3));
        assert!(out.accuracy < 0.5); // exited before stabilization
    }

    #[test]
    fn ua_converges() {
        let t = synthetic_trace(30, 5);
        let mut p = UniqueAnswersPolicy::new(32, 1, 10_000);
        let out = replay(&t, &mut p, Signal::MainPrefixed, false);
        assert_eq!(out.exit_line, Some(5));
        assert!(out.accuracy > 0.9);
    }

    #[test]
    fn overhead_charged_when_requested() {
        let t = synthetic_trace(10, 4);
        let mut p = UniqueAnswersPolicy::new(8, 1, 10_000);
        let charged = replay(&t, &mut p, Signal::MainPrefixed, true);
        let free = replay(&t, &mut p, Signal::MainPrefixed, false);
        assert!(charged.overhead_tokens > 0);
        assert_eq!(free.overhead_tokens, 0);
        // #UA@8 charges 8*(3+2)=40 tokens per evaluated line
        assert_eq!(charged.overhead_tokens, charged.exit_line.unwrap() * 40);
    }

    #[test]
    fn proxy_signal_used() {
        let t = synthetic_trace(30, 5);
        let mut p = EatPolicy::new(0.2, 1e-2, 10_000);
        let out = replay(&t, &mut p, Signal::Proxy, false);
        assert!(out.exit_line.is_some());
    }

    #[test]
    fn lazy_replay_matches_tree_replay() {
        let t = synthetic_trace(30, 5);
        let text = t.to_json().to_string();
        let sc = JsonScanner::new(&text);
        let make = |which: usize| -> Box<dyn crate::exit::ExitPolicy> {
            match which {
                0 => Box::new(EatPolicy::new(0.2, 0.05, 10_000)),
                1 => Box::new(TokenBudgetPolicy::new(9)),
                _ => Box::new(UniqueAnswersPolicy::new(32, 1, 10_000)),
            }
        };
        for which in 0..3 {
            for signal in [
                Signal::MainPrefixed,
                Signal::MainPlain,
                Signal::Proxy,
                Signal::Newline,
            ] {
                for charge in [false, true] {
                    let tree = replay(&t, &mut *make(which), signal, charge);
                    let lazy =
                        replay_scanned(&sc, &mut *make(which), signal, charge)
                            .unwrap();
                    assert_eq!(lazy.exit_line, tree.exit_line);
                    assert_eq!(lazy.exit_reason, tree.exit_reason);
                    assert_eq!(
                        lazy.reasoning_tokens,
                        tree.reasoning_tokens
                    );
                    assert_eq!(lazy.overhead_tokens, tree.overhead_tokens);
                    assert_eq!(
                        lazy.accuracy.to_bits(),
                        tree.accuracy.to_bits()
                    );
                    assert_eq!(
                        lazy.accuracy_exact.to_bits(),
                        tree.accuracy_exact.to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn no_exit_consumes_whole_trace() {
        let t = synthetic_trace(8, 100); // never stabilizes
        let mut p = EatPolicy::new(0.2, 1e-12, 10_000);
        let out = replay(&t, &mut p, Signal::MainPrefixed, false);
        assert_eq!(out.exit_line, None);
        assert_eq!(out.reasoning_tokens, 24);
    }
}
