//! Per-figure reproduction drivers (DESIGN.md §4 experiment index).
//!
//! Each `fig_*` function regenerates one figure of the paper from recorded
//! trace sets (offline replay) or live runs (black-box figures), writes a
//! CSV under `results/`, and prints the headline comparison the figure
//! supports. EXPERIMENTS.md quotes these outputs.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::blackbox::{run_blackbox, LatencyModel};
use crate::config::ServeConfig;
use crate::datasets::Dataset;
use crate::exit::EatPolicy;
use crate::monitor::{EmaVar, Trace};
use crate::runtime::{Backend, Runtime};

use super::replay::{replay, Signal};
use super::store::TraceSet;
use super::sweep::{
    default_deltas, default_token_budgets, sweep_confidence, sweep_eat,
    sweep_token, sweep_ua, Curve,
};

pub struct FigureCtx {
    /// Directory with recorded trace sets (from `repro trace`).
    pub traces_dir: PathBuf,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    pub cfg: ServeConfig,
}

impl FigureCtx {
    pub fn new(traces_dir: impl Into<PathBuf>, out_dir: impl Into<PathBuf>) -> FigureCtx {
        FigureCtx {
            traces_dir: traces_dir.into(),
            out_dir: out_dir.into(),
            cfg: ServeConfig::default(),
        }
    }

    pub fn load(&self, dataset: &str) -> Result<TraceSet> {
        TraceSet::load(&self.traces_dir.join(format!("{dataset}.json")))
            .with_context(|| {
                format!("traces for `{dataset}` missing; run: repro trace --dataset {dataset}")
            })
    }

    fn csv(&self, name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{header}")?;
        for r in rows {
            writeln!(f, "{r}")?;
        }
        println!("  wrote {} ({} rows)", path.display(), rows.len());
        Ok(path)
    }

    fn curves_csv(&self, name: &str, curves: &[Curve]) -> Result<()> {
        let mut rows = Vec::new();
        for c in curves {
            for p in &c.points {
                rows.push(format!(
                    "{},{:.6e},{:.1},{:.4},{:.2}",
                    c.label, p.threshold, p.total_tokens, p.agg_pass1, p.mean_exit_line
                ));
            }
        }
        self.csv(name, "policy,threshold,total_tokens,agg_pass1,mean_exit_line", &rows)?;
        for c in curves {
            println!("    AUC[{}] = {:.4}", c.label, c.auc());
        }
        Ok(())
    }
}

fn opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.4}")).unwrap_or_default()
}

/// Pick up to `k` representative traces (longest reasoning first).
fn samples(ts: &TraceSet, k: usize) -> Vec<&Trace> {
    let mut idx: Vec<&Trace> = ts.traces.iter().filter(|t| t.points.len() >= 4).collect();
    idx.sort_by_key(|t| std::cmp::Reverse(t.points.len()));
    idx.into_iter().take(k).collect()
}

// ---------------------------------------------------------------------------
// Fig. 1 — Pass@1(Avg@128), #UA@128 and EAT trajectories; overthinking
// ---------------------------------------------------------------------------

pub fn fig1(ctx: &FigureCtx) -> Result<()> {
    println!("[fig1] trajectory panels + overthinking quantification");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    for t in samples(&ts, 6) {
        for p in &t.points {
            rows.push(format!(
                "{},{},{},{:.4},{},{:.4}",
                t.question_id, p.line, p.tokens, p.pass1_avgk, p.unique_answers, p.eat
            ));
        }
    }
    ctx.csv("fig1_trajectories.csv", "question,line,tokens,pass1_avg128,ua128,eat", &rows)?;

    // The §3.3/App. B claim: Pass@1 saturates early; remaining tokens are
    // overthinking. Report the mean saturation fraction.
    let mut fracs = Vec::new();
    for t in &ts.traces {
        if let Some(final_p) = t.points.last().map(|p| p.pass1_avgk) {
            if final_p < 0.8 || t.points.len() < 3 {
                continue;
            }
            let sat = t
                .points
                .iter()
                .find(|p| p.pass1_avgk >= 0.9 * final_p)
                .map(|p| p.tokens as f64);
            if let (Some(sat), Some(last)) = (sat, t.points.last().map(|p| p.tokens as f64)) {
                fracs.push(sat / last.max(1.0));
            }
        }
    }
    let mean_frac = crate::util::stats::mean(&fracs);
    println!(
        "  Pass@1 saturates after {:.1}% of the generated reasoning on average \
         (paper: often within the first 10-20% of the budget); n={}",
        100.0 * mean_frac,
        fracs.len()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — EAT + EMA variance + threshold exits on GPQA
// ---------------------------------------------------------------------------

pub fn fig2(ctx: &FigureCtx) -> Result<()> {
    println!("[fig2] EAT / Vhat / exit markers on synth-gpqa (solvable subset)");
    let ts = ctx.load("synth-gpqa")?.filter_solvable(0.8);
    let mut rows = Vec::new();
    for t in samples(&ts, 4) {
        let mut policy = EatPolicy::new(ctx.cfg.alpha, ctx.cfg.delta, usize::MAX);
        let out = replay(t, &mut policy, Signal::MainPrefixed, false);
        let exit_line = out.exit_line.unwrap_or(usize::MAX);
        let mut ema = EmaVar::new(ctx.cfg.alpha);
        for p in &t.points {
            let vhat = ema.update(p.eat);
            rows.push(format!(
                "{},{},{:.4},{:.4},{:.6e},{},{}",
                t.question_id,
                p.line,
                p.pass1_avgk,
                p.eat,
                vhat,
                ctx.cfg.delta,
                (p.line == exit_line) as u8
            ));
        }
        println!(
            "  q{}: exit at line {:?} of {}, pass1 {:.2}",
            t.question_id,
            out.exit_line,
            t.points.len(),
            out.accuracy
        );
    }
    ctx.csv("fig2_exits.csv", "question,line,pass1,eat,vhat,delta,exit", &rows)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — headline efficiency curves (EAT self/proxy vs token budget)
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &FigureCtx) -> Result<()> {
    println!("[fig3] Agg. Pass@1 vs total tokens (the headline result)");
    for ds in ["synth-math500", "synth-aime"] {
        let ts = ctx.load(ds)?;
        let t_max = ctx.cfg.max_think_tokens;
        let curves = vec![
            sweep_token(&ts, &default_token_budgets(t_max), "token-budget"),
            sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat-self"),
            sweep_eat(&ts, Signal::Proxy, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat-proxy"),
        ];
        println!("  dataset {ds}:");
        ctx.curves_csv(&format!("fig3_{ds}.csv"), &curves)?;
        let chart_series: Vec<(&str, Vec<(f64, f64)>)> = curves
            .iter()
            .map(|c| {
                (
                    c.label.as_str(),
                    c.points
                        .iter()
                        .map(|p| (p.total_tokens, p.agg_pass1))
                        .collect(),
                )
            })
            .collect();
        print!(
            "{}",
            super::plot::ascii_chart(
                &format!("Agg. Pass@1 vs total tokens — {ds}"),
                &chart_series,
                64,
                14,
            )
        );

        // headline: token saving at iso-accuracy (best accuracy reachable
        // by the token baseline, matched by EAT)
        let tok = &curves[0];
        let eat = &curves[1];
        let best_tok_acc = tok.points.iter().map(|p| p.agg_pass1).fold(0.0, f64::max);
        let target = 0.98 * best_tok_acc;
        if let (Some(te), Some(tt)) = (eat.tokens_at_accuracy(target), tok.tokens_at_accuracy(target)) {
            println!(
                "    iso-accuracy({:.3}): EAT {:.0} vs token {:.0} tokens -> {:.1}% saving \
                 (paper: 12-22%)",
                target,
                te,
                tt,
                100.0 * (1.0 - te / tt)
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — EAT vs confidence (Eq. 16) at two EMA windows
// ---------------------------------------------------------------------------

pub fn fig4(ctx: &FigureCtx) -> Result<()> {
    println!("[fig4] EAT vs rollout confidence at alpha in {{0.2, 0.4}}");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    let mut curves = Vec::new();
    for &alpha in &[0.2, 0.4] {
        curves.push(sweep_eat(
            &ts, Signal::MainPrefixed, alpha, &default_deltas(), t_max, true,
            &format!("eat-a{alpha}"),
        ));
        curves.push(sweep_confidence(
            &ts, alpha, &default_deltas(), t_max, true,
            &format!("conf-a{alpha}"),
        ));
    }
    curves.push(sweep_token(&ts, &default_token_budgets(t_max), "token-budget"));
    ctx.curves_csv("fig4_confidence.csv", &curves)?;
    println!("    (confidence curves charge the 5-token rollout; EAT charges its 3-token probe)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5a / Fig. 18 — black-box early stop of the streaming "Claude" API
// ---------------------------------------------------------------------------

pub fn fig5a(ctx: &FigureCtx, rt: &Runtime, n_questions: usize) -> Result<()> {
    println!("[fig5a/fig18] black-box: local proxy early-stops the streaming API");
    // each question runs through the black-box coordinator on a virtual
    // clock (DESIGN.md §3.6): arrival gaps come from the seeded latency
    // model and proxy_compute_ms from the deterministic cost model, so
    // this CSV is a pure function of the seed — no wall time leaks in
    let ds = Dataset::synth_aime(&rt.vocab, n_questions.max(3), ctx.cfg.seed);
    let mut rows = Vec::new();
    let mut saved_total = 0.0;
    for q in ds.questions.iter().take(n_questions) {
        let res = run_blackbox(rt, &ctx.cfg, q, LatencyModel::default(), 12, ctx.cfg.seed + q.id as u64)?;
        for p in &res.points {
            rows.push(format!(
                "{},{},{},{:.4},{:.6e},{:.1},{:.2},{}",
                q.id, p.chunk, p.tokens_seen, p.eat, p.vhat, p.arrival_gap_ms,
                p.proxy_compute_ms,
                (Some(p.chunk) == res.stop_chunk) as u8
            ));
        }
        saved_total += res.saved_ms;
        println!(
            "  q{} ({}): stop at chunk {:?} ({} of <= {} tokens), saved ~{:.1}s simulated, correct={}",
            q.id,
            if q.solvable() { "solvable" } else { "unsolvable" },
            res.stop_chunk,
            res.tokens_at_stop,
            res.total_tokens_available,
            res.saved_ms / 1e3,
            res.correct
        );
    }
    ctx.csv(
        "fig5a_blackbox.csv",
        "question,chunk,tokens,eat,vhat,arrival_gap_ms,proxy_compute_ms,stop",
        &rows,
    )?;
    println!("  total simulated remote time saved: {:.1}s", saved_total / 1e3);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6a/6b — #UA@K sensitivity and true token cost
// ---------------------------------------------------------------------------

pub fn fig6a(ctx: &FigureCtx) -> Result<()> {
    println!("[fig6a] #UA@K accuracy-vs-token curves (K sensitivity)");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    let mut curves = vec![
        sweep_token(&ts, &default_token_budgets(t_max), "token-budget"),
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat"),
    ];
    for &k in &[8usize, 16, 32] {
        curves.push(sweep_ua(&ts, k, &[1, 2, 3], t_max, false, 1, &format!("ua-k{k}")));
    }
    ctx.curves_csv("fig6a_ua_sensitivity.csv", &curves)?;
    Ok(())
}

pub fn fig6b(ctx: &FigureCtx) -> Result<()> {
    println!("[fig6b] actual token cost including rollouts (Delta=1)");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    let mut curves = vec![sweep_eat(
        &ts, Signal::MainPrefixed, ctx.cfg.alpha, &[1e-3], t_max, true, "eat",
    )];
    for &k in &[8usize, 16, 32] {
        curves.push(sweep_ua(&ts, k, &[1], t_max, true, 1, &format!("ua-k{k}")));
    }
    ctx.curves_csv("fig6b_ua_true_cost.csv", &curves)?;
    let eat_t = curves[0].points[0].total_tokens;
    let ua32_t = curves[3].points[0].total_tokens;
    println!(
        "    #UA@32 consumes {:.1}x the tokens of EAT at matched thresholds \
         (paper Fig. 6b: 'very significant')",
        ua32_t / eat_t
    );
    Ok(())
}

/// Fig. 6c — runtime: EAT probe vs K-rollout wall-clock vs context length.
pub fn fig6c(ctx: &FigureCtx, rt: &Runtime) -> Result<()> {
    println!("[fig6c] measured probe vs rollout runtime (live)");
    let vocab = rt.vocab;
    let ds = Dataset::synth_aime(&vocab, 3, 7);
    let q = &ds.questions[0];
    let mut prompt = q.prompt.clone();
    prompt.push(vocab.think);
    let (mut logits, mut cache) = rt.main.prefill(&prompt)?;
    let sampler = crate::sampler::Sampler::new(ctx.cfg.temperature, ctx.cfg.top_p);
    let mut rng = crate::util::rng::Rng::new(1);
    let suffix = vocab.suffix_prefixed();
    let mut rows = Vec::new();
    // grow the context; at checkpoints measure probe + K=1 rollout cost
    for step in 1..=(rt.main.seq_len() - prompt.len() - 10) {
        let tok = {
            let t = sampler.sample(&logits, &mut rng);
            if t == vocab.ethink || t == vocab.eos { vocab.nl } else { t }
        };
        logits = rt.main.decode(&mut cache, tok)?;
        if step % 16 == 0 {
            let t0 = std::time::Instant::now();
            for _ in 0..5 {
                rt.main.probe(&cache, &suffix)?;
            }
            let probe_ms = t0.elapsed().as_secs_f64() * 1e3 / 5.0;
            let t1 = std::time::Instant::now();
            let mut fork = rt.main.fork(&cache)?;
            let mut lg = Vec::new();
            for &t in &suffix {
                lg = rt.main.decode(&mut fork, t)?;
            }
            for _ in 0..2 {
                let t = crate::sampler::argmax(&lg);
                lg = rt.main.decode(&mut fork, t)?;
            }
            let rollout_ms = t1.elapsed().as_secs_f64() * 1e3;
            rows.push(format!("{},{:.3},{:.3}", cache.pos(), probe_ms, rollout_ms));
            println!(
                "  ctx {:>4} tokens: EAT probe {:.2} ms, 1 rollout {:.2} ms ({:.1}x)",
                cache.pos(), probe_ms, rollout_ms, rollout_ms / probe_ms
            );
        }
    }
    ctx.csv("fig6c_runtime.csv", "context_tokens,probe_ms,rollout1_ms", &rows)?;
    println!("    (K=32 rollouts would cost 32x the rollout column; see bench_rollout)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7 — EAT at conclusion (compute) lines is near-monotone
// ---------------------------------------------------------------------------

pub fn fig7(ctx: &FigureCtx) -> Result<()> {
    println!("[fig7] EAT at answer/conclusion lines vs all lines");
    let ts = ctx.load("synth-aime")?;
    let mut rows = Vec::new();
    let mut viol_all = 0usize;
    let mut n_all = 0usize;
    let mut viol_concl = 0usize;
    let mut n_concl = 0usize;
    for t in samples(&ts, 6) {
        let mut prev_all: Option<f64> = None;
        let mut prev_c: Option<f64> = None;
        for p in &t.points {
            // compute lines (the per-step conclusions) are the first n_ops
            // lines; verify lines re-confirm afterwards
            let conclusion = p.line <= t.n_ops;
            rows.push(format!(
                "{},{},{:.4},{}",
                t.question_id, p.line, p.eat, conclusion as u8
            ));
            if let Some(pr) = prev_all {
                n_all += 1;
                viol_all += (p.eat > pr + 0.05) as usize;
            }
            prev_all = Some(p.eat);
            if conclusion {
                if let Some(pr) = prev_c {
                    n_concl += 1;
                    viol_concl += (p.eat > pr + 0.05) as usize;
                }
                prev_c = Some(p.eat);
            }
        }
    }
    ctx.csv("fig7_conclusions.csv", "question,line,eat,is_conclusion", &rows)?;
    println!(
        "  monotonicity violations: all lines {:.1}% vs conclusion lines {:.1}% \
         (paper: conclusion positions are smoother)",
        100.0 * viol_all as f64 / n_all.max(1) as f64,
        100.0 * viol_concl as f64 / n_concl.max(1) as f64
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 — prefix-string ablation (Eq. 12 vs Eq. 13)
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &FigureCtx) -> Result<()> {
    println!("[fig8] EAT with vs without the 'Final answer:' prefix string");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    for t in samples(&ts, 6) {
        for p in &t.points {
            rows.push(format!(
                "{},{},{:.4},{},{:.4}",
                t.question_id, p.line, p.eat, opt(p.eat_plain), p.pass1_avgk
            ));
        }
    }
    ctx.csv("fig8_prefix.csv", "question,line,eat_prefixed,eat_plain,pass1", &rows)?;

    // quantify informativeness: correlation of each variant with Pass@1
    let (mut c_pref, mut c_plain) = (Vec::new(), Vec::new());
    for t in &ts.traces {
        for p in &t.points {
            c_pref.push((p.eat, p.pass1_avgk));
            if let Some(e) = p.eat_plain {
                c_plain.push((e, p.pass1_avgk));
            }
        }
    }
    println!(
        "  corr(EAT, Pass@1): prefixed {:.3} vs plain {:.3} \
         (paper App. D: prefix needed for informativeness)",
        pearson(&c_pref),
        pearson(&c_plain)
    );
    Ok(())
}

fn pearson(xy: &[(f64, f64)]) -> f64 {
    let n = xy.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xy.iter().map(|p| p.0).sum::<f64>() / n;
    let my = xy.iter().map(|p| p.1).sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xy {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-12)
}

// ---------------------------------------------------------------------------
// Fig. 9 — entropy-after-newline control (Eq. 14)
// ---------------------------------------------------------------------------

pub fn fig9(ctx: &FigureCtx) -> Result<()> {
    println!("[fig9] EAT vs entropy-after-newline (App. F control)");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    for t in samples(&ts, 6) {
        for p in &t.points {
            rows.push(format!(
                "{},{},{:.4},{},{:.4}",
                t.question_id, p.line, p.eat, opt(p.eat_newline), p.pass1_avgk
            ));
        }
    }
    ctx.csv("fig9_newline.csv", "question,line,eat,entropy_after_nl,pass1", &rows)?;
    let (mut a, mut b) = (Vec::new(), Vec::new());
    for t in &ts.traces {
        for p in &t.points {
            a.push((p.eat, p.pass1_avgk));
            if let Some(e) = p.eat_newline {
                b.push((e, p.pass1_avgk));
            }
        }
    }
    println!(
        "  |corr with Pass@1|: EAT {:.3} vs newline-entropy {:.3} (paper: newline is less informative)",
        pearson(&a).abs(),
        pearson(&b).abs()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10 — alternative evaluation frequencies (App. G)
// ---------------------------------------------------------------------------

pub fn fig10(ctx: &FigureCtx) -> Result<()> {
    println!("[fig10] EAT sub-sampled at every S lines (frequency ablation)");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    for t in samples(&ts, 4) {
        for &s in &[1usize, 2, 4] {
            for p in t.points.iter().filter(|p| p.line % s == 0) {
                rows.push(format!("{},{},{},{:.4}", t.question_id, s, p.tokens, p.eat));
            }
        }
    }
    ctx.csv("fig10_frequency.csv", "question,stride,tokens,eat", &rows)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 11 — second reasoning model (proxy reasons, monitored by self/main)
// ---------------------------------------------------------------------------

pub fn fig11(ctx: &FigureCtx) -> Result<()> {
    println!("[fig11] proxy as the reasoning model (cross-model EAT)");
    let ts = ctx.load("synth-math500-proxyreason")?;
    let t_max = ctx.cfg.max_think_tokens;
    let curves = vec![
        sweep_token(&ts, &default_token_budgets(t_max), "token-budget"),
        // in these traces: `eat` = the reasoner's own (proxy) entropy,
        // `eat_proxy` = the *main* model monitoring the proxy's reasoning
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat-self(proxy)"),
        sweep_eat(&ts, Signal::Proxy, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat-cross(main)"),
    ];
    ctx.curves_csv("fig11_proxy_reasoner.csv", &curves)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 12 — tool-calling (App. I.2): reasoning unnecessary
// ---------------------------------------------------------------------------

pub fn fig12(ctx: &FigureCtx) -> Result<()> {
    println!("[fig12] tool-calling: EAT informative but reasoning unnecessary");
    let ts = ctx.load("synth-tool")?;
    let mut rows = Vec::new();
    let mut first_line_pass1 = Vec::new();
    for t in &ts.traces {
        if let Some(p0) = t.points.first() {
            first_line_pass1.push(p0.pass1_avgk);
        }
        for p in &t.points {
            rows.push(format!(
                "{},{},{:.4},{:.4}",
                t.question_id, p.line, p.pass1_avgk, p.eat
            ));
        }
    }
    ctx.csv("fig12_tool.csv", "question,line,pass1,eat", &rows)?;
    println!(
        "  mean Pass@1 at the FIRST reasoning line: {:.3} (paper: high from the start -> \
         no test-time scaling, EAT not advantageous here)",
        crate::util::stats::mean(&first_line_pass1)
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 13 — alpha ablation (App. I.3): AUC vs EMA timescale, +- prefix
// ---------------------------------------------------------------------------

pub fn fig13(ctx: &FigureCtx) -> Result<()> {
    println!("[fig13] AUC vs EMA timescale alpha, with/without prefix");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    let token_auc = sweep_token(&ts, &default_token_budgets(t_max), "token").auc();
    let mut rows = Vec::new();
    for &alpha in &[0.01, 0.05, 0.1, 0.2, 0.4, 0.5, 0.6, 0.8] {
        let pref = sweep_eat(&ts, Signal::MainPrefixed, alpha, &default_deltas(), t_max, false, "p").auc();
        let plain = sweep_eat(&ts, Signal::MainPlain, alpha, &default_deltas(), t_max, false, "n").auc();
        rows.push(format!("{alpha},{pref:.4},{plain:.4},{token_auc:.4}"));
        println!(
            "  alpha={alpha:<5} AUC prefixed {pref:.4}  plain {plain:.4}  (token baseline {token_auc:.4})"
        );
    }
    ctx.csv("fig13_alpha.csv", "alpha,auc_prefixed,auc_plain,auc_token", &rows)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 14/15/17 — error analyses
// ---------------------------------------------------------------------------

pub fn fig14(ctx: &FigureCtx) -> Result<()> {
    println!("[fig14] unsolvable questions: EAT never stabilizes");
    let ts = ctx.load("synth-gpqa")?;
    let mut rows = Vec::new();
    let mut budget_exhausted = 0usize;
    let mut n = 0usize;
    let mut plain_tokens = 0usize;
    let mut stall_tokens = 0usize;
    let mut stall_gaveup = 0usize;
    for t in ts.traces.iter().filter(|t| t.answer.is_none()) {
        n += 1;
        let mut policy = EatPolicy::new(ctx.cfg.alpha, ctx.cfg.delta, usize::MAX);
        let out = replay(t, &mut policy, Signal::MainPrefixed, false);
        budget_exhausted += out.exit_line.is_none() as usize;
        plain_tokens += out.reasoning_tokens;
        // §6 extension: the stall-aware policy gives up early instead
        let mut stall =
            crate::exit::StallAwareEatPolicy::new(ctx.cfg.alpha, ctx.cfg.delta, usize::MAX);
        let out2 = replay(t, &mut stall, Signal::MainPrefixed, false);
        stall_tokens += out2.reasoning_tokens;
        stall_gaveup +=
            (out2.exit_reason == crate::exit::ExitReason::Stalled) as usize;
        for p in &t.points {
            rows.push(format!("{},{},{:.4},{:.4}", t.question_id, p.line, p.eat, p.pass1_avgk));
        }
    }
    ctx.csv("fig14_unsolvable.csv", "question,line,eat,pass1", &rows)?;
    println!(
        "  {}/{} unsolvable questions never trigger the EAT exit (paper \
         App. I.4 / §6 limitation: budget burned on unsolvables)",
        budget_exhausted, n
    );
    println!(
        "  §6 extension (StallAwareEatPolicy): {stall_gaveup}/{n} give up early, \
         {stall_tokens} vs {plain_tokens} tokens ({:.0}% saved on unsolvables)",
        100.0 * (1.0 - stall_tokens as f64 / plain_tokens.max(1) as f64)
    );
    Ok(())
}

pub fn fig15(ctx: &FigureCtx) -> Result<()> {
    println!("[fig15] out-of-distribution questions with decaying Pass@1");
    let ts = ctx.load("synth-gpqa")?;
    let mut rows = Vec::new();
    for t in ts.traces.iter().filter(|t| t.n_ops >= 11) {
        for p in &t.points {
            rows.push(format!("{},{},{:.4},{:.4}", t.question_id, p.line, p.eat, p.pass1_avgk));
        }
    }
    ctx.csv("fig15_ood.csv", "question,line,eat,pass1", &rows)?;
    Ok(())
}

pub fn fig16(ctx: &FigureCtx) -> Result<()> {
    println!("[fig16] EAT and confidence both stabilize as Pass@1 plateaus");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    for t in samples(&ts, 4) {
        for p in &t.points {
            rows.push(format!(
                "{},{},{:.4},{},{:.4}",
                t.question_id, p.line, p.eat, opt(p.confidence), p.pass1_avgk
            ));
        }
    }
    ctx.csv("fig16_eat_conf.csv", "question,line,eat,confidence,pass1", &rows)?;
    Ok(())
}

pub fn fig17(ctx: &FigureCtx) -> Result<()> {
    println!("[fig17] hardest synth-math500 questions (low final Pass@1)");
    let ts = ctx.load("synth-math500")?;
    let mut rows = Vec::new();
    let mut hard: Vec<&Trace> = ts
        .traces
        .iter()
        .filter(|t| t.points.last().map(|p| p.pass1_avgk < 0.5).unwrap_or(false))
        .collect();
    hard.sort_by_key(|t| t.question_id);
    for t in hard.iter().take(6) {
        for p in &t.points {
            rows.push(format!(
                "{},{},{:.4},{},{:.4}",
                t.question_id, p.line, p.eat, p.unique_answers, p.pass1_avgk
            ));
        }
    }
    ctx.csv("fig17_hard.csv", "question,line,eat,ua128,pass1", &rows)?;
    println!("  {} hard questions found", hard.len());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 19 — #UA@32 at matched budget (sparse evaluation)
// ---------------------------------------------------------------------------

pub fn fig19(ctx: &FigureCtx) -> Result<()> {
    println!("[fig19] #UA@32 evaluated sparsely (budget-matched) vs EAT");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    // cost match: EAT costs 3 tokens/line; #UA@32 costs 32*5=160/eval ->
    // evaluating every 8 lines still charges 20 tokens/line-equivalent
    let curves = vec![
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, true, "eat-every-line"),
        sweep_ua(&ts, 32, &[1, 2, 3], t_max, true, 8, "ua32-every-8"),
        sweep_ua(&ts, 32, &[1, 2, 3], t_max, true, 1, "ua32-every-line"),
    ];
    ctx.curves_csv("fig19_budget_matched.csv", &curves)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 20 — unfiltered GPQA
// ---------------------------------------------------------------------------

pub fn fig20(ctx: &FigureCtx) -> Result<()> {
    println!("[fig20] unfiltered synth-gpqa (EAT loses its edge; paper App. I.4)");
    let ts = ctx.load("synth-gpqa")?;
    let t_max = ctx.cfg.max_think_tokens;
    let curves = vec![
        sweep_token(&ts, &default_token_budgets(t_max), "token-budget"),
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat"),
    ];
    ctx.curves_csv("fig20_gpqa_unfiltered.csv", &curves)?;
    let filtered = ctx.load("synth-gpqa")?.filter_solvable(0.8);
    let fc = vec![
        sweep_token(&filtered, &default_token_budgets(t_max), "token-budget"),
        sweep_eat(&filtered, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat"),
    ];
    println!("  unfiltered: token AUC {:.4} vs EAT AUC {:.4}", curves[0].auc(), curves[1].auc());
    println!("  solvable-only: token AUC {:.4} vs EAT AUC {:.4}", fc[0].auc(), fc[1].auc());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 21 — efficiency including EAT evaluation overhead
// ---------------------------------------------------------------------------

pub fn fig21(ctx: &FigureCtx) -> Result<()> {
    println!("[fig21] curves with the EAT probe overhead charged");
    let ts = ctx.load("synth-math500")?;
    let t_max = ctx.cfg.max_think_tokens;
    let curves = vec![
        sweep_token(&ts, &default_token_budgets(t_max), "token-budget"),
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, false, "eat-free"),
        sweep_eat(&ts, Signal::MainPrefixed, ctx.cfg.alpha, &default_deltas(), t_max, true, "eat-charged"),
    ];
    ctx.curves_csv("fig21_overhead.csv", &curves)?;
    println!("    (paper Fig. 21: EAT still wins with overhead counted, thanks to the 1-token probe)");
    Ok(())
}

// ---------------------------------------------------------------------------
// dispatcher
// ---------------------------------------------------------------------------

/// Figures that replay recorded traces only.
pub fn run_offline(ctx: &FigureCtx, fig: &str) -> Result<bool> {
    match fig {
        "1" => fig1(ctx)?,
        "2" => fig2(ctx)?,
        "3" => fig3(ctx)?,
        "4" => fig4(ctx)?,
        "6a" => fig6a(ctx)?,
        "6b" => fig6b(ctx)?,
        "7" => fig7(ctx)?,
        "8" => fig8(ctx)?,
        "9" => fig9(ctx)?,
        "10" => fig10(ctx)?,
        "11" => fig11(ctx)?,
        "12" => fig12(ctx)?,
        "13" => fig13(ctx)?,
        "14" => fig14(ctx)?,
        "15" => fig15(ctx)?,
        "16" => fig16(ctx)?,
        "17" => fig17(ctx)?,
        "19" => fig19(ctx)?,
        "20" => fig20(ctx)?,
        "21" => fig21(ctx)?,
        _ => return Ok(false),
    }
    Ok(true)
}

/// Figures that need the live runtime.
pub fn run_live(ctx: &FigureCtx, rt: &Runtime, fig: &str) -> Result<bool> {
    match fig {
        "5a" | "18" => fig5a(ctx, rt, 8)?,
        "6c" => fig6c(ctx, rt)?,
        _ => return Ok(false),
    }
    Ok(true)
}

pub const OFFLINE_FIGS: &[&str] = &[
    "1", "2", "3", "4", "6a", "6b", "7", "8", "9", "10", "11", "12", "13",
    "14", "15", "16", "17", "19", "20", "21",
];
pub const LIVE_FIGS: &[&str] = &["5a", "6c", "18"];

/// Make sure `path` exists (directory creation helper for the CLI).
pub fn ensure_dir(path: &Path) -> Result<()> {
    std::fs::create_dir_all(path)?;
    Ok(())
}
