//! Evaluation harness: trace generation (App. H simulated early exiting),
//! persistence, offline replay, threshold sweeps and figure drivers.

pub mod figures;
pub mod plot;
pub mod replay;
pub mod store;
pub mod sweep;
pub mod tracegen;
pub mod zoo;

pub use replay::{replay, replay_scanned, ReplayOutcome, Signal};
pub use store::TraceSet;
pub use sweep::{Curve, CurvePoint};
pub use tracegen::TraceGen;
pub use zoo::{run_zoo, zoo_report_json, ZooConfig, ZooReport};
