//! Token sampling: temperature + top-p (nucleus), following the paper's
//! decoding configuration (App. H: temperature 0.6, top-p 0.95, the
//! DeepSeek model-card recommendation).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct Sampler {
    pub temperature: f32,
    pub top_p: f32,
}

impl Sampler {
    pub fn new(temperature: f32, top_p: f32) -> Sampler {
        assert!(temperature >= 0.0 && top_p > 0.0 && top_p <= 1.0);
        Sampler { temperature, top_p }
    }

    pub fn greedy() -> Sampler {
        Sampler {
            temperature: 0.0,
            top_p: 1.0,
        }
    }

    /// Softmax with temperature; numerically stable.
    pub fn probs(&self, logits: &[f32]) -> Vec<f32> {
        softmax_t(logits, self.temperature.max(1e-4))
    }

    /// Sample a token id from logits.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        if self.temperature == 0.0 {
            return argmax(logits);
        }
        let mut probs = self.probs(logits);
        if self.top_p < 1.0 {
            truncate_top_p(&mut probs, self.top_p);
        }
        sample_from(&probs, rng)
    }

    /// Log-probability (natural log, full distribution at temperature 1 —
    /// what the confidence baseline Eq. 16 uses) of a given token.
    pub fn logprob(logits: &[f32], token: u32) -> f64 {
        let p = softmax_t(logits, 1.0);
        (p[token as usize] as f64).max(1e-30).ln()
    }
}

pub fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best as u32
}

fn softmax_t(logits: &[f32], t: f32) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::MIN, f32::max);
    let mut out: Vec<f32> = logits
        .iter()
        .map(|&z| (((z - m) / t) as f64).exp() as f32)
        .collect();
    let sum: f32 = out.iter().sum();
    for p in &mut out {
        *p /= sum;
    }
    out
}

/// Zero out everything outside the smallest prefix of probability mass
/// >= top_p (after sorting by probability), renormalize.
fn truncate_top_p(probs: &mut [f32], top_p: f32) {
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut cum = 0.0f32;
    let mut keep = vec![false; probs.len()];
    for &i in &idx {
        keep[i] = true;
        cum += probs[i];
        if cum >= top_p {
            break;
        }
    }
    let mut sum = 0.0f32;
    for i in 0..probs.len() {
        if !keep[i] {
            probs[i] = 0.0;
        }
        sum += probs[i];
    }
    for p in probs.iter_mut() {
        *p /= sum;
    }
}

fn sample_from(probs: &[f32], rng: &mut Rng) -> u32 {
    let r = rng.f32();
    let mut cum = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        cum += p;
        if r < cum {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 5.0, -2.0, 4.9];
        let mut rng = Rng::new(0);
        assert_eq!(Sampler::greedy().sample(&logits, &mut rng), 1);
    }

    #[test]
    fn probs_sum_to_one() {
        let s = Sampler::new(0.6, 0.95);
        let p = s.probs(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn low_temperature_sharpens() {
        let logits = [1.0f32, 2.0];
        let hot = Sampler::new(2.0, 1.0).probs(&logits);
        let cold = Sampler::new(0.2, 1.0).probs(&logits);
        assert!(cold[1] > hot[1]);
    }

    #[test]
    fn top_p_excludes_tail() {
        // token 2 has tiny probability; with top_p=0.9 it must never be
        // sampled
        let logits = vec![5.0f32, 5.0, -10.0];
        let s = Sampler::new(1.0, 0.9);
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            assert_ne!(s.sample(&logits, &mut rng), 2);
        }
    }

    #[test]
    fn sampling_matches_distribution() {
        let logits = vec![0.0f32, (2.0f32).ln()]; // p = [1/3, 2/3]
        let s = Sampler::new(1.0, 1.0);
        let mut rng = Rng::new(2);
        let n = 30_000;
        let ones: usize = (0..n)
            .map(|_| s.sample(&logits, &mut rng) as usize)
            .sum();
        let frac = ones as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn logprob_consistent() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let lp: f64 = (0..3).map(|t| Sampler::logprob(&logits, t).exp()).sum();
        assert!((lp - 1.0).abs() < 1e-6);
    }

    #[test]
    fn deterministic_with_seed() {
        let logits = vec![0.5f32, 0.7, 0.1, 2.0];
        let s = Sampler::new(0.6, 0.95);
        let a: Vec<u32> = {
            let mut rng = Rng::new(77);
            (0..50).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        let b: Vec<u32> = {
            let mut rng = Rng::new(77);
            (0..50).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }
}
