//! # eat-serve — EAT: Entropy After `</think>` early-exit reasoning serving
//!
//! A three-layer Rust + JAX + Pallas reproduction of "EAT: Entropy After
//! </Think> for reasoning model early exiting" (2025). The Rust layer is
//! the serving coordinator (this crate); the JAX/Pallas layers are
//! build-time only and ship as AOT-compiled HLO artifacts executed through
//! the PJRT C API (feature `pjrt`). Without artifacts, a deterministic
//! in-process reference backend drives the identical serving stack.
//!
//! Layout (see DESIGN.md):
//!  * [`runtime`]     — the `Backend` trait (prefill / decode / probe /
//!    fork / fused `decode_batch`) with two impls: PJRT artifacts and
//!    the in-process reference model
//!  * [`coordinator`] — split-phase sessions (`poll()`/`complete_*`),
//!    continuous batcher with an EAT-aware preemptive scheduler (one
//!    fused decode per tick, preempt/resume by page repin with a
//!    re-prefill fallback, virtual-clock deterministic simulation),
//!    slot-major batch cache store, paged copy-on-write KV subsystem
//!  * [`exit`]        — EAT (Alg. 1) + token/#UA@K/confidence baselines
//!  * [`monitor`]     — EMA variance estimator + trajectory records
//!  * [`blackbox`]    — the black-box setting as a coordinator workload:
//!    split-phase stream sessions, batched remote-main + local-proxy
//!    lanes, clock-scheduled chunk arrivals (deterministic under a
//!    virtual clock)
//!  * [`eval`]        — trace generation, offline replay, figure drivers
//!  * [`datasets`]    — synthetic benchmark analogues
//!  * [`util`]        — hand-rolled substrates (JSON, CLI, RNG, stats)

pub mod blackbox;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod eval;
pub mod exit;
pub mod monitor;
pub mod runtime;
pub mod sampler;
pub mod util;
pub mod vocab;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
/// Default results directory.
pub const DEFAULT_RESULTS: &str = "results";
/// Default recorded-traces directory.
pub const DEFAULT_TRACES: &str = "results/traces";
