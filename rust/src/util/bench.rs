//! Micro-benchmark harness substrate (no `criterion` offline).
//!
//! Warmup + adaptive-iteration timing with mean/p50/p95 reporting in a
//! stable text format that `cargo bench` prints and EXPERIMENTS.md
//! quotes, plus a `BENCH_<name>.json` snapshot writer so CI and the
//! experiment log can diff machine-readable numbers instead of scraping
//! stdout.

use std::time::{Duration, Instant};

use anyhow::Context;

use super::json::{Json, JsonScanner};
use super::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>6}  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
        ])
    }
}

/// The snapshot document: the timing rows plus bench-specific context
/// (e.g. the cluster goodput-scaling table) under caller-chosen keys.
pub fn snapshot_json(bench: &str, results: &[BenchResult], extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("bench", Json::str(bench)),
        ("results", Json::arr(results.iter().map(BenchResult::to_json))),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

/// Snapshot directory: `$BENCH_DIR` when set (CI collects per-run
/// artifact dirs), else the workspace root — so every bench's
/// `BENCH_<name>.json` lands in one place no matter what working
/// directory the bench was invoked from.
pub fn snapshot_dir() -> std::path::PathBuf {
    match std::env::var_os("BENCH_DIR") {
        Some(d) if !d.is_empty() => std::path::PathBuf::from(d),
        _ => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
    }
}

/// Write `BENCH_<bench>.json` into [`snapshot_dir`] and return the path.
pub fn write_snapshot(
    bench: &str,
    results: &[BenchResult],
    extra: Vec<(&str, Json)>,
) -> anyhow::Result<String> {
    let dir = snapshot_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, snapshot_json(bench, results, extra).to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path.display().to_string())
}

/// One row-level comparison out of [`diff_snapshots`].
#[derive(Debug)]
pub struct BenchDelta {
    pub name: String,
    pub base_mean_ns: f64,
    pub new_mean_ns: f64,
    /// `new/base - 1`: positive = slower.
    pub ratio: f64,
    /// Slowed down past the tolerance.
    pub regressed: bool,
}

/// Row sets of two snapshots, matched by row name.
#[derive(Debug, Default)]
pub struct SnapshotDiff {
    /// Rows present on both sides, in base order.
    pub deltas: Vec<BenchDelta>,
    /// Row names only in the base snapshot (bench removed).
    pub only_base: Vec<String>,
    /// Row names only in the new snapshot (bench added).
    pub only_new: Vec<String>,
}

impl SnapshotDiff {
    pub fn regressions(&self) -> usize {
        self.deltas.iter().filter(|d| d.regressed).count()
    }
}

/// Compare two `BENCH_*.json` snapshot documents (the CI regression
/// gate). Rows are matched by name; a row regresses when its mean slows
/// down by more than `tol` (`tol = 1.0` → flag at > 2x slower — micro
/// benches are noisy, the gate is for order-of-magnitude cliffs). Rows
/// present on only one side are reported, never failed. Reads go through
/// [`JsonScanner`], so the CI diff path exercises the lazy layer.
pub fn diff_snapshots(base: &str, new: &str, tol: f64) -> anyhow::Result<SnapshotDiff> {
    let base_rows = snapshot_rows(base).context("base snapshot")?;
    let new_rows = snapshot_rows(new).context("new snapshot")?;
    let mut diff = SnapshotDiff::default();
    for (name, base_mean) in &base_rows {
        match new_rows.iter().find(|(n, _)| n == name) {
            Some((_, new_mean)) => {
                let ratio = new_mean / base_mean - 1.0;
                diff.deltas.push(BenchDelta {
                    name: name.clone(),
                    base_mean_ns: *base_mean,
                    new_mean_ns: *new_mean,
                    ratio,
                    regressed: ratio > tol,
                });
            }
            None => diff.only_base.push(name.clone()),
        }
    }
    for (name, _) in &new_rows {
        if !base_rows.iter().any(|(n, _)| n == name) {
            diff.only_new.push(name.clone());
        }
    }
    Ok(diff)
}

fn snapshot_rows(text: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let sc = JsonScanner::new(text);
    let rows = sc
        .path(&["results"])
        .context("snapshot carries no `results` array")?;
    rows.array_items()
        .map(|r| Ok((r.req_str("name")?.into_owned(), r.req_num("mean_ns")?)))
        .collect()
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Per-call sampling budget: 2 s, or `BENCH_BUDGET_MS` when set (the CI
/// bench-smoke job shrinks it so the snapshots stay cheap to produce —
/// fewer samples, same schema).
pub fn default_budget() -> Duration {
    std::env::var("BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_secs(2))
}

/// Time `f` with `warmup` throwaway calls, then sample wall-clock per call
/// until the [`default_budget`] elapses (at least `min_iters` samples).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, default_budget(), 3, 10, &mut f)
}

pub fn bench_with<F: FnMut()>(
    name: &str,
    budget: Duration,
    warmup: usize,
    min_iters: usize,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: percentile(&samples, 0.5),
        p95_ns: percentile(&samples, 0.95),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with(
            "noop",
            Duration::from_millis(20),
            1,
            5,
            &mut || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn snapshot_json_carries_rows_and_extras() {
        let r = BenchResult {
            name: "x/y".into(),
            iters: 3,
            mean_ns: 10.0,
            p50_ns: 9.0,
            p95_ns: 12.0,
        };
        let j = snapshot_json("demo", &[r], vec![("note", Json::str("hi"))]);
        assert_eq!(j.get("bench").as_str(), Some("demo"));
        assert_eq!(j.get("note").as_str(), Some("hi"));
        let rows = j.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").as_str(), Some("x/y"));
        assert_eq!(rows[0].get("iters").as_usize(), Some(3));
    }

    fn snap(rows: &[(&str, f64)]) -> String {
        let results: Vec<BenchResult> = rows
            .iter()
            .map(|(n, m)| BenchResult {
                name: n.to_string(),
                iters: 10,
                mean_ns: *m,
                p50_ns: *m,
                p95_ns: *m,
            })
            .collect();
        snapshot_json("t", &results, vec![]).to_string()
    }

    #[test]
    fn diff_flags_only_regressions_past_tolerance() {
        let base = snap(&[("a", 100.0), ("b", 100.0), ("gone", 5.0)]);
        let new = snap(&[("a", 150.0), ("b", 250.0), ("fresh", 5.0)]);
        let d = diff_snapshots(&base, &new, 1.0).unwrap();
        assert_eq!(d.deltas.len(), 2);
        let a = &d.deltas[0];
        assert_eq!(a.name, "a");
        assert!(!a.regressed, "1.5x is within tol=1.0");
        assert!((a.ratio - 0.5).abs() < 1e-12);
        let b = &d.deltas[1];
        assert!(b.regressed, "2.5x must regress at tol=1.0");
        assert_eq!(d.regressions(), 1);
        assert_eq!(d.only_base, vec!["gone".to_string()]);
        assert_eq!(d.only_new, vec!["fresh".to_string()]);
        // speedups never regress, at any tolerance
        let faster = snap(&[("a", 10.0), ("b", 1.0), ("gone", 5.0)]);
        assert_eq!(diff_snapshots(&base, &faster, 0.0).unwrap().regressions(), 0);
    }

    #[test]
    fn diff_rejects_malformed_snapshots() {
        assert!(diff_snapshots("{}", "{}", 1.0).is_err());
        let ok = snap(&[("a", 1.0)]);
        assert!(diff_snapshots(&ok, "{\"results\":[{}]}", 1.0).is_err());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
