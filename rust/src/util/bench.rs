//! Micro-benchmark harness substrate (no `criterion` offline).
//!
//! Warmup + adaptive-iteration timing with mean/p50/p95 reporting in a
//! stable text format that `cargo bench` prints and EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

use super::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>6}  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time `f` with `warmup` throwaway calls, then sample wall-clock per call
/// until `budget` elapses (at least `min_iters` samples).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, Duration::from_secs(2), 3, 10, &mut f)
}

pub fn bench_with<F: FnMut()>(
    name: &str,
    budget: Duration,
    warmup: usize,
    min_iters: usize,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: percentile(&samples, 0.5),
        p95_ns: percentile(&samples, 0.95),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with(
            "noop",
            Duration::from_millis(20),
            1,
            5,
            &mut || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
