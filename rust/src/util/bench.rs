//! Micro-benchmark harness substrate (no `criterion` offline).
//!
//! Warmup + adaptive-iteration timing with mean/p50/p95 reporting in a
//! stable text format that `cargo bench` prints and EXPERIMENTS.md
//! quotes, plus a `BENCH_<name>.json` snapshot writer so CI and the
//! experiment log can diff machine-readable numbers instead of scraping
//! stdout.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>6}  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
        ])
    }
}

/// The snapshot document: the timing rows plus bench-specific context
/// (e.g. the cluster goodput-scaling table) under caller-chosen keys.
pub fn snapshot_json(bench: &str, results: &[BenchResult], extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("bench", Json::str(bench)),
        ("results", Json::arr(results.iter().map(BenchResult::to_json))),
    ];
    pairs.extend(extra);
    Json::obj(pairs)
}

/// Write `BENCH_<bench>.json` in the working directory (the repo root
/// under `cargo bench`) and return the path.
pub fn write_snapshot(
    bench: &str,
    results: &[BenchResult],
    extra: Vec<(&str, Json)>,
) -> anyhow::Result<String> {
    let path = format!("BENCH_{bench}.json");
    std::fs::write(&path, snapshot_json(bench, results, extra).to_string())?;
    Ok(path)
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Time `f` with `warmup` throwaway calls, then sample wall-clock per call
/// until `budget` elapses (at least `min_iters` samples).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with(name, Duration::from_secs(2), 3, 10, &mut f)
}

pub fn bench_with<F: FnMut()>(
    name: &str,
    budget: Duration,
    warmup: usize,
    min_iters: usize,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: percentile(&samples, 0.5),
        p95_ns: percentile(&samples, 0.95),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_with(
            "noop",
            Duration::from_millis(20),
            1,
            5,
            &mut || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn snapshot_json_carries_rows_and_extras() {
        let r = BenchResult {
            name: "x/y".into(),
            iters: 3,
            mean_ns: 10.0,
            p50_ns: 9.0,
            p95_ns: 12.0,
        };
        let j = snapshot_json("demo", &[r], vec![("note", Json::str("hi"))]);
        assert_eq!(j.get("bench").as_str(), Some("demo"));
        assert_eq!(j.get("note").as_str(), Some("hi"));
        let rows = j.get("results").as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").as_str(), Some("x/y"));
        assert_eq!(rows[0].get("iters").as_usize(), Some(3));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
