//! Statistics helpers shared by metrics, benches and the eval harness.

/// Mean of a slice (0.0 if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation over an already-sorted slice —
/// the single definition [`percentile`] and [`Summary`] share.
fn interp_sorted(s: &[f64], q: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Percentile by linear interpolation on a *sorted copy* (q in [0,1]).
/// NaN-safe: `total_cmp` gives NaNs a defined order (after +inf), so a
/// degenerate sample shifts the top quantiles instead of panicking the
/// whole metrics snapshot.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    interp_sorted(&s, q)
}

/// Area under a (x, y) curve by trapezoid rule after sorting by x and
/// normalizing x to [0, 1] — the paper's AUC efficiency metric (§5.2):
/// "a more efficient early exiting approach should have a larger area
/// under the [Agg. pass@1 vs token usage] curve".
///
/// NaN contract: points with a non-finite coordinate are **skipped**,
/// never propagated and never a panic — one degenerate trace must not
/// take down a whole sweep report. [`auc_normalized_counting`] exposes
/// how many points were dropped so reports can surface it.
pub fn auc_normalized(points: &[(f64, f64)]) -> f64 {
    auc_normalized_counting(points).0
}

/// [`auc_normalized`] plus the number of non-finite points skipped.
/// Fewer than two finite points leave no area to integrate: (0.0, n).
pub fn auc_normalized_counting(points: &[(f64, f64)]) -> (f64, usize) {
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    let skipped = points.len() - pts.len();
    if pts.len() < 2 {
        return (0.0, skipped);
    }
    pts.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (x0, x1) = (pts[0].0, pts[pts.len() - 1].0);
    let span = (x1 - x0).max(1e-12);
    let mut area = 0.0;
    for w in pts.windows(2) {
        let dx = (w[1].0 - w[0].0) / span;
        area += dx * 0.5 * (w[0].1 + w[1].1);
    }
    (area, skipped)
}

/// Simple latency histogram for the serving metrics, with a lazily
/// maintained sort: every accessor used to clone + sort the sample vec
/// (~10 sorts per metrics snapshot); now `record` marks the store
/// unsorted and the first quantile accessor after a batch of records
/// sorts once in place — a full `to_json()` snapshot costs one sort.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: std::cell::RefCell<Vec<f64>>,
    sorted: std::cell::Cell<bool>,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.get_mut().push(v);
        self.sorted.set(false);
        self.sum += v;
    }

    pub fn count(&self) -> usize {
        self.samples.borrow().len()
    }

    fn ensure_sorted(&self) {
        if !self.sorted.get() {
            // total_cmp: a NaN sample sorts last instead of panicking
            // mid-snapshot (same contract as `percentile`)
            self.samples.borrow_mut().sort_by(f64::total_cmp);
            self.sorted.set(true);
        }
    }

    /// Percentile by linear interpolation on the (lazily) sorted store
    /// — same definition as [`percentile`], without the per-call sort.
    fn quantile(&self, q: f64) -> f64 {
        self.ensure_sorted();
        interp_sorted(&self.samples.borrow(), q)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Largest sample (0.0 if empty, like `mean`/`percentile`).
    pub fn max(&self) -> f64 {
        self.quantile(1.0)
    }

    /// Smallest sample (0.0 if empty, like `mean`/`percentile`).
    pub fn min(&self) -> f64 {
        self.quantile(0.0)
    }

    pub fn total(&self) -> f64 {
        self.sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn auc_of_flat_curve_is_height() {
        let pts = [(0.0, 0.8), (5.0, 0.8), (10.0, 0.8)];
        assert!((auc_normalized(&pts) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn auc_higher_for_earlier_rise() {
        // curve A reaches accuracy 1.0 with fewer tokens than curve B
        let a = [(0.0, 0.0), (2.0, 1.0), (10.0, 1.0)];
        let b = [(0.0, 0.0), (8.0, 1.0), (10.0, 1.0)];
        assert!(auc_normalized(&a) > auc_normalized(&b));
    }

    #[test]
    fn percentile_with_nan_does_not_panic() {
        // the old partial_cmp().unwrap() sort panicked here; total_cmp
        // orders (positive) NaN after +inf, so low quantiles are clean
        // and only the top of the distribution reads the NaN
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 1.0).is_nan());
    }

    #[test]
    fn auc_skips_non_finite_points_with_count() {
        let clean = [(0.0, 0.0), (2.0, 1.0), (10.0, 1.0)];
        let mut dirty = clean.to_vec();
        dirty.push((5.0, f64::NAN));
        dirty.push((f64::INFINITY, 0.5));
        let (auc, skipped) = auc_normalized_counting(&dirty);
        assert_eq!(skipped, 2);
        assert!((auc - auc_normalized(&clean)).abs() < 1e-12);
        // fewer than two finite points: no area, still no panic
        assert_eq!(auc_normalized_counting(&[(f64::NAN, 1.0)]), (0.0, 1));
        assert_eq!(auc_normalized(&[(1.0, f64::NAN), (2.0, 0.5)]), 0.0);
    }

    #[test]
    fn summary_with_nan_sample_does_not_panic() {
        let mut s = Summary::new();
        s.record(1.0);
        s.record(f64::NAN);
        s.record(3.0);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min(), 1.0);
        assert!(s.max().is_nan());
    }

    #[test]
    fn empty_summary_reports_zeros_not_sentinels() {
        let s = Summary::new();
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn summary_interleaved_records_and_reads() {
        // the lazy sort must re-arm after every record
        let mut s = Summary::new();
        s.record(5.0);
        assert_eq!(s.p50(), 5.0);
        s.record(1.0);
        s.record(9.0);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 9.0);
        s.record(0.5);
        assert_eq!(s.min(), 0.5);
        assert!((s.total() - 15.5).abs() < 1e-12);
    }

    #[test]
    fn summary() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p95() >= 95.0 && s.p95() <= 96.0);
        assert!(s.p99() >= 99.0 && s.p99() <= 100.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.min(), 1.0);
    }
}
