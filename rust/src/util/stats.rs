//! Statistics helpers shared by metrics, benches and the eval harness.

/// Mean of a slice (0.0 if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation over an already-sorted slice —
/// the single definition [`percentile`] and [`Summary`] share.
fn interp_sorted(s: &[f64], q: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Percentile by linear interpolation on a *sorted copy* (q in [0,1]).
/// NaN-safe: `total_cmp` gives NaNs a defined order (after +inf), so a
/// degenerate sample shifts the top quantiles instead of panicking the
/// whole metrics snapshot.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    interp_sorted(&s, q)
}

/// Area under a (x, y) curve by trapezoid rule after sorting by x and
/// normalizing x to [0, 1] — the paper's AUC efficiency metric (§5.2):
/// "a more efficient early exiting approach should have a larger area
/// under the [Agg. pass@1 vs token usage] curve".
///
/// NaN contract: points with a non-finite coordinate are **skipped**,
/// never propagated and never a panic — one degenerate trace must not
/// take down a whole sweep report. [`auc_normalized_counting`] exposes
/// how many points were dropped so reports can surface it.
pub fn auc_normalized(points: &[(f64, f64)]) -> f64 {
    auc_normalized_counting(points).0
}

/// [`auc_normalized`] plus the number of non-finite points skipped.
/// Fewer than two finite points leave no area to integrate: (0.0, n).
pub fn auc_normalized_counting(points: &[(f64, f64)]) -> (f64, usize) {
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    let skipped = points.len() - pts.len();
    if pts.len() < 2 {
        return (0.0, skipped);
    }
    pts.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (x0, x1) = (pts[0].0, pts[pts.len() - 1].0);
    let span = (x1 - x0).max(1e-12);
    let mut area = 0.0;
    for w in pts.windows(2) {
        let dx = (w[1].0 - w[0].0) / span;
        area += dx * 0.5 * (w[0].1 + w[1].1);
    }
    (area, skipped)
}

/// SplitMix64 finalizer: the reservoir's deterministic priority hash.
/// A bijection on u64, so distinct insertion indices always get
/// distinct priorities (total order, no tiebreak needed).
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Samples a [`Summary`] retains before switching from exact quantiles
/// to reservoir quantiles. High enough that every pre-existing workload
/// (sims, tests, CI determinism runs — thousands of requests) stays in
/// exact mode with byte-identical JSON; only unbounded soak-scale runs
/// cross it.
pub const DEFAULT_SUMMARY_CAP: usize = 1 << 16;

/// Exact streaming moments (Welford) plus total_cmp min/max: O(1) state
/// per series, for metrics that must stay memory-bounded at soak scale.
/// Count, mean, variance and the extremes are exact for *all* recorded
/// values no matter how many.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamingMoments {
    n: u64,
    mean: f64,
    m2: f64,
    mn: f64,
    mx: f64,
}

impl StreamingMoments {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.n += 1;
        if self.n == 1 {
            self.mn = v;
            self.mx = v;
        } else {
            // total_cmp extremes: same NaN contract as `percentile`
            if v.total_cmp(&self.mn).is_lt() {
                self.mn = v;
            }
            if v.total_cmp(&self.mx).is_gt() {
                self.mx = v;
            }
        }
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0.0 if empty, like [`mean`]).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance (0.0 below two samples, like [`variance`]).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mn
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mx
        }
    }
}

/// Latency histogram for the serving metrics, memory-bounded
/// (DESIGN.md §3.10) with a lazily maintained sort.
///
/// Up to `cap` samples ([`DEFAULT_SUMMARY_CAP`] for [`Summary::new`])
/// every value is retained and quantiles are **exact** — bit-for-bit
/// the pre-bounded behavior, which is what keeps all pinned metrics
/// JSON unchanged. Past the cap the store becomes a deterministic
/// reservoir: each record's keep/evict priority is [`mix64`] of its
/// insertion index (a pure function of the record *sequence*, never of
/// the values or of anything wall-clock), the `cap` lowest-priority
/// samples survive, and quantiles interpolate over the survivors.
/// `count`/`mean`/`total` and `min`/`max` stay exact at any scale via
/// streaming fields.
///
/// The lazy sort is unchanged from PR 4: `record` marks the store
/// dirty and the first quantile accessor after a batch of records
/// sorts once — a full `to_json()` snapshot costs one sort.
#[derive(Debug, Clone)]
pub struct Summary {
    cap: usize,
    /// Total records ever (exact, beyond the reservoir).
    n: u64,
    sum: f64,
    /// Exact extremes over all records (total_cmp order).
    mn: f64,
    mx: f64,
    /// Retained samples as (priority, value bits); a max-heap by
    /// priority once at capacity, so eviction is O(log cap).
    entries: std::collections::BinaryHeap<(u64, u64)>,
    /// Lazily (re)built sorted view of the retained values.
    sorted: std::cell::RefCell<Vec<f64>>,
    dirty: std::cell::Cell<bool>,
}

impl Default for Summary {
    fn default() -> Self {
        Summary::bounded(DEFAULT_SUMMARY_CAP)
    }
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Summary holding at most `cap` samples (min 2). Below `cap` it is
    /// exact; above, a deterministic reservoir.
    pub fn bounded(cap: usize) -> Self {
        Summary {
            cap: cap.max(2),
            n: 0,
            sum: 0.0,
            mn: 0.0,
            mx: 0.0,
            entries: std::collections::BinaryHeap::new(),
            sorted: std::cell::RefCell::new(Vec::new()),
            dirty: std::cell::Cell::new(false),
        }
    }

    pub fn record(&mut self, v: f64) {
        let pri = mix64(self.n);
        self.n += 1;
        self.sum += v;
        if self.n == 1 {
            self.mn = v;
            self.mx = v;
        } else {
            if v.total_cmp(&self.mn).is_lt() {
                self.mn = v;
            }
            if v.total_cmp(&self.mx).is_gt() {
                self.mx = v;
            }
        }
        self.entries.push((pri, v.to_bits()));
        if self.entries.len() > self.cap {
            self.entries.pop();
        }
        self.dirty.set(true);
    }

    /// Total records ever (not just retained ones).
    pub fn count(&self) -> usize {
        self.n as usize
    }

    /// Samples actually retained (== count below the cap).
    pub fn retained(&self) -> usize {
        self.entries.len()
    }

    /// True once the reservoir has started evicting (quantiles are
    /// interpolated over a sample of the stream, extremes stay exact).
    pub fn is_sampled(&self) -> bool {
        (self.n as usize) > self.entries.len()
    }

    fn ensure_sorted(&self) {
        if self.dirty.get() {
            let mut s = self.sorted.borrow_mut();
            s.clear();
            s.extend(self.entries.iter().map(|&(_, bits)| f64::from_bits(bits)));
            // total_cmp: a NaN sample sorts last instead of panicking
            // mid-snapshot (same contract as `percentile`)
            s.sort_by(f64::total_cmp);
            self.dirty.set(false);
        }
    }

    /// Percentile by linear interpolation on the (lazily) sorted store
    /// — same definition as [`percentile`], without the per-call sort.
    fn quantile(&self, q: f64) -> f64 {
        self.ensure_sorted();
        interp_sorted(&self.sorted.borrow(), q)
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Largest sample ever (0.0 if empty, like `mean`/`percentile`) —
    /// exact even when the reservoir has evicted it.
    pub fn max(&self) -> f64 {
        if self.is_sampled() {
            self.mx
        } else {
            self.quantile(1.0)
        }
    }

    /// Smallest sample ever (0.0 if empty, like `mean`/`percentile`) —
    /// exact even when the reservoir has evicted it.
    pub fn min(&self) -> f64 {
        if self.is_sampled() {
            self.mn
        } else {
            self.quantile(0.0)
        }
    }

    pub fn total(&self) -> f64 {
        self.sum
    }

    /// Approximate heap footprint (capacity-based): bounded by the cap,
    /// never by the stream length.
    pub fn approx_bytes(&self) -> usize {
        self.entries.capacity() * std::mem::size_of::<(u64, u64)>()
            + self.sorted.borrow().capacity() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn auc_of_flat_curve_is_height() {
        let pts = [(0.0, 0.8), (5.0, 0.8), (10.0, 0.8)];
        assert!((auc_normalized(&pts) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn auc_higher_for_earlier_rise() {
        // curve A reaches accuracy 1.0 with fewer tokens than curve B
        let a = [(0.0, 0.0), (2.0, 1.0), (10.0, 1.0)];
        let b = [(0.0, 0.0), (8.0, 1.0), (10.0, 1.0)];
        assert!(auc_normalized(&a) > auc_normalized(&b));
    }

    #[test]
    fn percentile_with_nan_does_not_panic() {
        // the old partial_cmp().unwrap() sort panicked here; total_cmp
        // orders (positive) NaN after +inf, so low quantiles are clean
        // and only the top of the distribution reads the NaN
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 1.0).is_nan());
    }

    #[test]
    fn auc_skips_non_finite_points_with_count() {
        let clean = [(0.0, 0.0), (2.0, 1.0), (10.0, 1.0)];
        let mut dirty = clean.to_vec();
        dirty.push((5.0, f64::NAN));
        dirty.push((f64::INFINITY, 0.5));
        let (auc, skipped) = auc_normalized_counting(&dirty);
        assert_eq!(skipped, 2);
        assert!((auc - auc_normalized(&clean)).abs() < 1e-12);
        // fewer than two finite points: no area, still no panic
        assert_eq!(auc_normalized_counting(&[(f64::NAN, 1.0)]), (0.0, 1));
        assert_eq!(auc_normalized(&[(1.0, f64::NAN), (2.0, 0.5)]), 0.0);
    }

    #[test]
    fn summary_with_nan_sample_does_not_panic() {
        let mut s = Summary::new();
        s.record(1.0);
        s.record(f64::NAN);
        s.record(3.0);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min(), 1.0);
        assert!(s.max().is_nan());
    }

    #[test]
    fn empty_summary_reports_zeros_not_sentinels() {
        let s = Summary::new();
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn summary_interleaved_records_and_reads() {
        // the lazy sort must re-arm after every record
        let mut s = Summary::new();
        s.record(5.0);
        assert_eq!(s.p50(), 5.0);
        s.record(1.0);
        s.record(9.0);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 9.0);
        s.record(0.5);
        assert_eq!(s.min(), 0.5);
        assert!((s.total() - 15.5).abs() < 1e-12);
    }

    #[test]
    fn summary() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p95() >= 95.0 && s.p95() <= 96.0);
        assert!(s.p99() >= 99.0 && s.p99() <= 100.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn bounded_summary_is_exact_below_the_cap() {
        // at or below the cap the retained multiset is the full stream,
        // so every accessor must agree with an effectively-unbounded
        // Summary bit for bit (the pinned-JSON invariant)
        let mut small = Summary::bounded(64);
        let mut big = Summary::bounded(1 << 20);
        for i in 0..64 {
            let v = ((i * 37) % 64) as f64 * 0.5;
            small.record(v);
            big.record(v);
        }
        assert!(!small.is_sampled());
        for q in [
            Summary::min,
            Summary::p50,
            Summary::p95,
            Summary::p99,
            Summary::max,
            Summary::mean,
        ] {
            assert_eq!(q(&small).to_bits(), q(&big).to_bits());
        }
        assert_eq!(small.count(), big.count());
    }

    #[test]
    fn bounded_summary_caps_memory_and_keeps_exact_aggregates() {
        let mut s = Summary::bounded(128);
        for i in 0..100_000u64 {
            s.record(i as f64);
        }
        assert!(s.is_sampled());
        assert_eq!(s.retained(), 128);
        assert_eq!(s.count(), 100_000);
        // count/mean/min/max/total are streaming-exact past the cap
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 99_999.0);
        assert!((s.mean() - 49_999.5).abs() < 1e-6);
        assert!((s.total() - 4_999_950_000.0).abs() < 1e-3);
        // quantiles are reservoir estimates over a uniform ramp: loose
        // but sane bounds
        assert!(s.p50() > 20_000.0 && s.p50() < 80_000.0, "p50 {}", s.p50());
        assert!(s.p95() > s.p50());
        // bounded by the cap, not the stream
        assert!(s.approx_bytes() < 128 * 64);
    }

    #[test]
    fn bounded_summary_reservoir_is_deterministic() {
        let run = || {
            let mut s = Summary::bounded(32);
            for i in 0..5_000u64 {
                s.record((i as f64).sin() * 100.0);
            }
            (
                s.p50().to_bits(),
                s.p95().to_bits(),
                s.p99().to_bits(),
                s.min().to_bits(),
                s.max().to_bits(),
            )
        };
        assert_eq!(run(), run(), "same stream must sample identically");
    }

    #[test]
    fn bounded_summary_keeps_nan_extremes_exact_past_the_cap() {
        let mut s = Summary::bounded(16);
        s.record(f64::NAN);
        for i in 0..1_000u64 {
            s.record(i as f64);
        }
        assert!(s.is_sampled());
        // total_cmp order: positive NaN outranks every finite max
        assert!(s.max().is_nan());
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn streaming_moments_match_batch_stats() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 131) % 997) as f64 * 0.25).collect();
        let mut m = StreamingMoments::new();
        for &x in &xs {
            m.record(x);
        }
        assert_eq!(m.count(), 1000);
        assert!((m.mean() - mean(&xs)).abs() < 1e-9);
        assert!((m.variance() - variance(&xs)).abs() < 1e-6);
        assert!((m.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(m.min(), percentile(&xs, 0.0));
        assert_eq!(m.max(), percentile(&xs, 1.0));
        // empty contract mirrors the slice helpers
        let e = StreamingMoments::new();
        assert_eq!((e.mean(), e.variance(), e.min(), e.max()), (0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn mix64_is_an_index_keyed_bijection_prefix() {
        // sanity: no collisions over a small prefix (mix64 is bijective,
        // so none can exist; this guards accidental edits)
        let mut seen: Vec<u64> = (0..4096u64).map(mix64).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 4096);
    }
}
