//! Deterministic RNG substrate: xoshiro256++ with a SplitMix64 seeder.
//!
//! The offline registry has no `rand`, and the serving stack needs
//! reproducible sampling anyway (the paper fixes decoding at temperature
//! 0.6 / top-p 0.95 and reports averages over seeded rollouts). All
//! randomness in the repo flows through this generator.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; tiny and fast.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give
    /// independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork an independent child stream (for per-request RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Standard normal via Box–Muller (used by workload generators).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (used by the arrival-process sim).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            // each bucket should get ~10000; allow wide slack
            assert!((8500..11500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
