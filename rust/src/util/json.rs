//! Minimal JSON parser/writer substrate + a lazy scanning layer.
//!
//! The offline registry has no `serde_json`, so the repo carries its own
//! small, well-tested JSON implementation. It supports the full JSON value
//! model (objects, arrays, strings with escapes, numbers, booleans, null)
//! which is all the artifact manifests, configs, trace stores and result
//! CSV/JSON writers need.
//!
//! Two read paths share one lexer (DESIGN.md §3.8):
//!
//!  * [`parse`] builds a full [`Json`] tree — the writer substrate and
//!    the differential oracle;
//!  * [`JsonScanner`] finds values by scanning bytes, zero-copy and
//!    allocation-free until a value is actually extracted — the hot
//!    path for trace replay, store loads and bench-snapshot diffing,
//!    where a reader wants three fields out of a megabyte document.
//!
//! Both decode strings through the same `scan_string_body` /
//! `unescape_body` pair, so escape semantics cannot drift; a seeded
//! differential property test (`tests/proptests.rs`) additionally pins
//! every scanner extraction byte-identical to the tree result.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering
/// (result files diff cleanly between runs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`")),
            _ => anyhow::bail!("expected JSON object while reading `{key}`"),
        }
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a usize"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a string"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected `{}` at byte {}, got `{}`",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected `{}` at {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let (end, has_escape) = scan_string_body(self.bytes, self.pos)?;
        let body = &self.bytes[self.pos..end];
        self.pos = end + 1; // past the closing quote
        Ok(unescape_body(body, has_escape)?.into_owned())
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        self.pos = scan_number(self.bytes, start);
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number `{s}`: {e}")
        })?))
    }
}

// ---------------------------------------------------------------------------
// Shared lexer pieces (tree parser + lazy scanner)
// ---------------------------------------------------------------------------

/// Find the end of a string body starting just past the opening quote.
/// Returns (index of the closing quote, whether any `\` escape occurred).
/// Input comes from a `&str`, so multibyte UTF-8 runs are walked
/// byte-wise (no continuation byte can alias `"` or `\`).
fn scan_string_body(bytes: &[u8], start: usize) -> anyhow::Result<(usize, bool)> {
    let mut pos = start;
    let mut has_escape = false;
    while let Some(&b) = bytes.get(pos) {
        match b {
            b'"' => return Ok((pos, has_escape)),
            b'\\' => {
                has_escape = true;
                pos += 2; // escape head consumed; \u digits are plain bytes
            }
            c if c < 0x20 => anyhow::bail!("control char in string"),
            _ => pos += 1,
        }
    }
    anyhow::bail!("unexpected end of JSON")
}

/// Decode a string body (escapes intact, quotes excluded). Zero-copy
/// when no escape occurred. Escape semantics are THE definition for both
/// read paths: `\u` decodes through `char::from_u32` with lone
/// surrogates mapped to U+FFFD, exactly like the original parser.
fn unescape_body(body: &[u8], has_escape: bool) -> anyhow::Result<std::borrow::Cow<'_, str>> {
    use std::borrow::Cow;
    let as_str = |b: &[u8]| -> anyhow::Result<&str> {
        std::str::from_utf8(b).map_err(|_| anyhow::anyhow!("invalid UTF-8"))
    };
    if !has_escape {
        return Ok(Cow::Borrowed(as_str(body)?));
    }
    let mut out = String::with_capacity(body.len());
    let mut pos = 0usize;
    while pos < body.len() {
        if body[pos] != b'\\' {
            // copy the maximal escape-free run in one shot
            let run = pos
                + body[pos..]
                    .iter()
                    .position(|&b| b == b'\\')
                    .unwrap_or(body.len() - pos);
            out.push_str(as_str(&body[pos..run])?);
            pos = run;
            continue;
        }
        let esc = *body
            .get(pos + 1)
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        pos += 2;
        match esc {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{0008}'),
            b'f' => out.push('\u{000C}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let mut code = 0u32;
                for _ in 0..4 {
                    let c = *body
                        .get(pos)
                        .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?
                        as char;
                    pos += 1;
                    code = code * 16
                        + c.to_digit(16)
                            .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                }
                out.push(char::from_u32(code).unwrap_or(char::REPLACEMENT_CHARACTER));
            }
            c => anyhow::bail!("bad escape `\\{}`", c as char),
        }
    }
    Ok(Cow::Owned(out))
}

/// Advance past a number token (sign, digits, fraction, exponent) and
/// return the end index. Shared by both read paths so they accept the
/// same lexical grammar; the caller validates via `str::parse::<f64>`.
fn scan_number(bytes: &[u8], start: usize) -> usize {
    let mut pos = start;
    if bytes.get(pos) == Some(&b'-') {
        pos += 1;
    }
    while matches!(bytes.get(pos), Some(c) if c.is_ascii_digit()) {
        pos += 1;
    }
    if bytes.get(pos) == Some(&b'.') {
        pos += 1;
        while matches!(bytes.get(pos), Some(c) if c.is_ascii_digit()) {
            pos += 1;
        }
    }
    if matches!(bytes.get(pos), Some(b'e' | b'E')) {
        pos += 1;
        if matches!(bytes.get(pos), Some(b'+' | b'-')) {
            pos += 1;
        }
        while matches!(bytes.get(pos), Some(c) if c.is_ascii_digit()) {
            pos += 1;
        }
    }
    pos
}

// ---------------------------------------------------------------------------
// Lazy scanning (ADR-002 idiom: find values by scanning bytes, no tree)
// ---------------------------------------------------------------------------

/// A lazy, zero-copy view over one JSON value in a text buffer.
///
/// Nothing is parsed up front: `path`/`entries`/`array_items` walk the
/// bytes with the same lexer the tree parser uses and return sub-views;
/// only a terminal `path_str` (on an escaped string) or `path_num`
/// allocates/converts. Partial extraction — a few fields out of a large
/// trace store or metrics snapshot — skips whole subtrees instead of
/// materializing them, which is where the measured `bench_json` speedup
/// comes from.
///
/// Error model: malformed input yields `None` (a miss), not a parse
/// error — the loaders convert misses into `anyhow` context. Duplicate
/// object keys resolve to the FIRST occurrence (the writer, backed by
/// `BTreeMap`, never emits duplicates).
#[derive(Clone, Copy)]
pub struct JsonScanner<'a> {
    bytes: &'a [u8],
}

impl<'a> JsonScanner<'a> {
    pub fn new(text: &'a str) -> JsonScanner<'a> {
        JsonScanner {
            bytes: text.as_bytes(),
        }
    }

    /// The exact byte slice of this view's value (whitespace trimmed,
    /// well-formedness checked by walking it). Cheap for scalars; for
    /// containers this walks the subtree, so hot paths prefer
    /// `entries`/`array_items`, which never need the end up front.
    fn trim_exact(&self) -> Option<&'a [u8]> {
        let s = skip_ws_at(self.bytes, 0);
        let e = skip_value(self.bytes, s)?;
        Some(&self.bytes[s..e])
    }

    /// Raw text of the value (escapes intact, subtrees unparsed).
    pub fn raw(&self) -> Option<&'a str> {
        std::str::from_utf8(self.trim_exact()?).ok()
    }

    /// Descend through object keys; `&[]` returns this value itself.
    /// Each hop short-circuits at the matching key — siblings after it
    /// are never scanned, siblings before it are skipped, not parsed.
    pub fn path(&self, path: &[&str]) -> Option<JsonScanner<'a>> {
        let mut cur = *self;
        for key in path {
            cur = cur
                .entries()
                .find(|(k, _)| k.as_ref() == *key)
                .map(|(_, v)| v)?;
        }
        Some(cur)
    }

    /// Iterate an object's `(key, value)` pairs in document order.
    /// Yields nothing when the value is not an object. Key decoding is
    /// zero-copy unless the key contains escapes.
    pub fn entries(&self) -> Entries<'a> {
        let s = skip_ws_at(self.bytes, 0);
        if self.bytes.get(s) != Some(&b'{') {
            return Entries::dead();
        }
        Entries {
            bytes: self.bytes,
            pos: s + 1,
            expect_first: true,
            dead: false,
        }
    }

    /// Iterate an array's elements as sub-scanners. Yields nothing when
    /// the value is not an array.
    pub fn array_items(&self) -> ArrayItems<'a> {
        let s = skip_ws_at(self.bytes, 0);
        if self.bytes.get(s) != Some(&b'[') {
            return ArrayItems::dead();
        }
        ArrayItems {
            bytes: self.bytes,
            pos: s + 1,
            expect_first: true,
            dead: false,
        }
    }

    /// Cheap first-byte check: does this value start an array? (No walk —
    /// loaders use it to reject wrong shapes before iterating.)
    pub fn is_array(&self) -> bool {
        self.bytes.get(skip_ws_at(self.bytes, 0)) == Some(&b'[')
    }

    // -- terminal extraction -----------------------------------------------

    /// String value at `path`, unescaped (`Cow::Borrowed` when the text
    /// carries no escapes).
    pub fn path_str(&self, path: &[&str]) -> Option<std::borrow::Cow<'a, str>> {
        let v = self.path(path)?;
        let s = skip_ws_at(v.bytes, 0);
        if v.bytes.get(s) != Some(&b'"') {
            return None;
        }
        let (end, has_escape) = scan_string_body(v.bytes, s + 1).ok()?;
        unescape_body(&v.bytes[s + 1..end], has_escape).ok()
    }

    /// Number value at `path` — the raw token through the same
    /// `str::parse::<f64>` the tree parser uses, so the result is
    /// bit-identical to `parse(...)` + `as_f64`.
    pub fn path_num(&self, path: &[&str]) -> Option<f64> {
        let v = self.path(path)?.trim_exact()?;
        match v.first() {
            Some(b'-') | Some(b'0'..=b'9') => {}
            _ => return None,
        }
        std::str::from_utf8(v).ok()?.parse::<f64>().ok()
    }

    pub fn path_bool(&self, path: &[&str]) -> Option<bool> {
        match self.path(path)?.trim_exact()? {
            b"true" => Some(true),
            b"false" => Some(false),
            _ => None,
        }
    }

    /// `path_num` with the same integrality/sign gate as
    /// [`Json::as_usize`].
    pub fn path_usize(&self, path: &[&str]) -> Option<usize> {
        let n = self.path_num(path)?;
        if n >= 0.0 && n.fract() == 0.0 {
            Some(n as usize)
        } else {
            None
        }
    }

    /// True when `path` exists and holds literal `null`.
    pub fn path_is_null(&self, path: &[&str]) -> bool {
        matches!(
            self.path(path).and_then(|v| v.trim_exact()),
            Some(b"null")
        )
    }

    // -- anyhow wrappers for loader code -----------------------------------

    pub fn req_num(&self, key: &str) -> anyhow::Result<f64> {
        self.path_num(&[key])
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric JSON key `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.path_usize(&[key])
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a usize"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<std::borrow::Cow<'a, str>> {
        self.path_str(&[key])
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a string"))
    }
}

pub struct Entries<'a> {
    bytes: &'a [u8],
    pos: usize,
    expect_first: bool,
    dead: bool,
}

impl<'a> Entries<'a> {
    fn dead() -> Entries<'a> {
        Entries {
            bytes: &[],
            pos: 0,
            expect_first: false,
            dead: true,
        }
    }
}

impl<'a> Iterator for Entries<'a> {
    type Item = (std::borrow::Cow<'a, str>, JsonScanner<'a>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.dead {
            return None;
        }
        self.pos = skip_ws_at(self.bytes, self.pos);
        if self.expect_first {
            self.expect_first = false;
            if self.bytes.get(self.pos) == Some(&b'}') {
                self.dead = true;
                return None;
            }
        } else {
            match self.bytes.get(self.pos) {
                Some(b',') => self.pos = skip_ws_at(self.bytes, self.pos + 1),
                _ => {
                    // `}` or malformed: either way the iteration is over
                    self.dead = true;
                    return None;
                }
            }
        }
        // key string
        if self.bytes.get(self.pos) != Some(&b'"') {
            self.dead = true;
            return None;
        }
        let (kend, kesc) = match scan_string_body(self.bytes, self.pos + 1) {
            Ok(r) => r,
            Err(_) => {
                self.dead = true;
                return None;
            }
        };
        let key = match unescape_body(&self.bytes[self.pos + 1..kend], kesc) {
            Ok(k) => k,
            Err(_) => {
                self.dead = true;
                return None;
            }
        };
        self.pos = skip_ws_at(self.bytes, kend + 1);
        if self.bytes.get(self.pos) != Some(&b':') {
            self.dead = true;
            return None;
        }
        let vstart = skip_ws_at(self.bytes, self.pos + 1);
        let vend = match skip_value(self.bytes, vstart) {
            Some(e) => e,
            None => {
                self.dead = true;
                return None;
            }
        };
        self.pos = vend;
        Some((
            key,
            JsonScanner {
                bytes: &self.bytes[vstart..vend],
            },
        ))
    }
}

pub struct ArrayItems<'a> {
    bytes: &'a [u8],
    pos: usize,
    expect_first: bool,
    dead: bool,
}

impl<'a> ArrayItems<'a> {
    fn dead() -> ArrayItems<'a> {
        ArrayItems {
            bytes: &[],
            pos: 0,
            expect_first: false,
            dead: true,
        }
    }
}

impl<'a> Iterator for ArrayItems<'a> {
    type Item = JsonScanner<'a>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.dead {
            return None;
        }
        self.pos = skip_ws_at(self.bytes, self.pos);
        if self.expect_first {
            self.expect_first = false;
            if self.bytes.get(self.pos) == Some(&b']') {
                self.dead = true;
                return None;
            }
        } else {
            match self.bytes.get(self.pos) {
                Some(b',') => self.pos = skip_ws_at(self.bytes, self.pos + 1),
                _ => {
                    self.dead = true;
                    return None;
                }
            }
        }
        let vstart = self.pos;
        let vend = match skip_value(self.bytes, vstart) {
            Some(e) => e,
            None => {
                self.dead = true;
                return None;
            }
        };
        self.pos = vend;
        Some(JsonScanner {
            bytes: &self.bytes[vstart..vend],
        })
    }
}

fn skip_ws_at(bytes: &[u8], mut pos: usize) -> usize {
    while matches!(bytes.get(pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        pos += 1;
    }
    pos
}

/// Advance past one complete value starting at `pos` (first non-ws
/// byte); returns the end index, or `None` on malformed input. This is
/// the scanner's workhorse: skipping a subtree costs a byte walk, not
/// an allocation.
fn skip_value(bytes: &[u8], pos: usize) -> Option<usize> {
    match bytes.get(pos)? {
        b'"' => scan_string_body(bytes, pos + 1).ok().map(|(e, _)| e + 1),
        b'{' => skip_container(bytes, pos, b'}', true),
        b'[' => skip_container(bytes, pos, b']', false),
        b't' => expect_literal(bytes, pos, b"true"),
        b'f' => expect_literal(bytes, pos, b"false"),
        b'n' => expect_literal(bytes, pos, b"null"),
        b'-' | b'0'..=b'9' => {
            let end = scan_number(bytes, pos);
            // reject a bare `-`/malformed token the f64 parser would
            std::str::from_utf8(&bytes[pos..end])
                .ok()?
                .parse::<f64>()
                .ok()?;
            Some(end)
        }
        _ => None,
    }
}

fn expect_literal(bytes: &[u8], pos: usize, word: &[u8]) -> Option<usize> {
    if bytes.get(pos..pos + word.len()) == Some(word) {
        Some(pos + word.len())
    } else {
        None
    }
}

fn skip_container(bytes: &[u8], open: usize, close: u8, keyed: bool) -> Option<usize> {
    let mut pos = skip_ws_at(bytes, open + 1);
    if bytes.get(pos) == Some(&close) {
        return Some(pos + 1);
    }
    loop {
        if keyed {
            if bytes.get(pos) != Some(&b'"') {
                return None;
            }
            let (kend, _) = scan_string_body(bytes, pos + 1).ok()?;
            pos = skip_ws_at(bytes, kend + 1);
            if bytes.get(pos) != Some(&b':') {
                return None;
            }
            pos = skip_ws_at(bytes, pos + 1);
        }
        pos = skip_value(bytes, pos)?;
        pos = skip_ws_at(bytes, pos);
        match bytes.get(pos)? {
            b',' => pos = skip_ws_at(bytes, pos + 1),
            c if *c == close => return Some(pos + 1),
            _ => return None,
        }
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":false}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"x":-1}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn req_helpers() {
        let v = parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
    }

    // -- lazy scanner -------------------------------------------------------

    #[test]
    fn scanner_finds_nested_paths() {
        let doc = r#"{"a": {"b": {"c": 42.5, "s": "hi"}}, "z": [1, 2]}"#;
        let sc = JsonScanner::new(doc);
        assert_eq!(sc.path_num(&["a", "b", "c"]), Some(42.5));
        assert_eq!(sc.path_str(&["a", "b", "s"]).as_deref(), Some("hi"));
        assert_eq!(sc.path_num(&["missing"]), None);
        assert_eq!(sc.path_num(&["a", "b", "s"]), None); // wrong type
    }

    #[test]
    fn scanner_array_items_and_entries() {
        let doc = r#" { "rows" : [ {"v": 1}, {"v": 2}, {"v": 3} ] } "#;
        let sc = JsonScanner::new(doc);
        let vals: Vec<f64> = sc
            .path(&["rows"])
            .unwrap()
            .array_items()
            .map(|it| it.path_num(&["v"]).unwrap())
            .collect();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        let keys: Vec<String> = sc.entries().map(|(k, _)| k.into_owned()).collect();
        assert_eq!(keys, vec!["rows"]);
        // non-containers iterate as empty, not panic
        assert_eq!(JsonScanner::new("3").array_items().count(), 0);
        assert_eq!(JsonScanner::new("3").entries().count(), 0);
    }

    #[test]
    fn scanner_matches_tree_on_escapes_and_unicode() {
        // \u escapes (incl. a lone surrogate -> U+FFFD), multibyte UTF-8,
        // writer-style control escapes: both read paths must agree
        for doc in [
            r#"{"k":"a\nb\t\"q\"\\"}"#,
            r#"{"k":"Aé\uD83D"}"#,
            r#"{"k":"héllo → wörld"}"#,
            r#"{"k":""}"#,
        ] {
            let tree = parse(doc).unwrap();
            let lazy = JsonScanner::new(doc).path_str(&["k"]).unwrap();
            assert_eq!(tree.get("k").as_str().unwrap(), lazy.as_ref(), "doc={doc}");
        }
    }

    #[test]
    fn scanner_numbers_bit_match_tree() {
        let doc = r#"{"a": -3.5e2, "b": 0.1, "c": 12345678901234, "d": -0.0}"#;
        let tree = parse(doc).unwrap();
        let sc = JsonScanner::new(doc);
        for k in ["a", "b", "c", "d"] {
            assert_eq!(
                tree.get(k).as_f64().unwrap().to_bits(),
                sc.path_num(&[k]).unwrap().to_bits(),
                "key {k}"
            );
        }
    }

    #[test]
    fn scanner_bool_null_usize() {
        let sc = JsonScanner::new(r#"{"t": true, "f": false, "n": null, "u": 7, "x": 7.5}"#);
        assert_eq!(sc.path_bool(&["t"]), Some(true));
        assert_eq!(sc.path_bool(&["f"]), Some(false));
        assert!(sc.path_is_null(&["n"]));
        assert!(!sc.path_is_null(&["t"]));
        assert!(!sc.path_is_null(&["missing"]));
        assert_eq!(sc.path_usize(&["u"]), Some(7));
        assert_eq!(sc.path_usize(&["x"]), None);
    }

    #[test]
    fn scanner_skips_malformed_gracefully() {
        // a miss, never a panic
        for doc in ["{", "[1,]", "{\"a\":}", "{\"a\" 1}", "nul", "-", "\"unterminated"] {
            let sc = JsonScanner::new(doc);
            assert_eq!(sc.path_num(&["a"]), None, "doc={doc}");
            assert!(sc.raw().is_none() || parse(doc).is_ok(), "doc={doc}");
        }
    }

    #[test]
    fn scanner_tolerates_interleaved_whitespace() {
        let doc = "\n{\t\"a\" :\r [ 1 ,\n 2 ] , \"b\" : { \"c\" : \"x\" } }\n";
        let sc = JsonScanner::new(doc);
        assert_eq!(sc.path(&["a"]).unwrap().array_items().count(), 2);
        assert_eq!(sc.path_str(&["b", "c"]).as_deref(), Some("x"));
    }

    #[test]
    fn scanner_raw_is_exact_value_text() {
        let sc = JsonScanner::new(r#"  {"a": [1, {"b": 2}]}  "#);
        assert_eq!(sc.raw(), Some(r#"{"a": [1, {"b": 2}]}"#));
        assert_eq!(sc.path(&["a"]).unwrap().raw(), Some(r#"[1, {"b": 2}]"#));
    }
}
