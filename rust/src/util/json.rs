//! Minimal JSON parser/writer substrate.
//!
//! The offline registry has no `serde_json`, so the repo carries its own
//! small, well-tested JSON implementation. It supports the full JSON value
//! model (objects, arrays, strings with escapes, numbers, booleans, null)
//! which is all the artifact manifests, configs, trace stores and result
//! CSV/JSON writers need.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering
/// (result files diff cleanly between runs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`")),
            _ => anyhow::bail!("expected JSON object while reading `{key}`"),
        }
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a usize"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` not a string"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected `{}` at byte {}, got `{}`",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => anyhow::bail!("unexpected `{}` at {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        out.push(
                            char::from_u32(code)
                                .unwrap_or(char::REPLACEMENT_CHARACTER),
                        );
                    }
                    c => anyhow::bail!("bad escape `\\{}`", c as char),
                },
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        anyhow::bail!("truncated UTF-8");
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number `{s}`: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":false}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"x":-1}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn req_helpers() {
        let v = parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req("missing").is_err());
    }
}
