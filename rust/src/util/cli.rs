//! Tiny argument-parsing substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.flags.get(key).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse `{v}`"))
        })
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.parse_or(key, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                panic!("--{key}: cannot parse `{v}`")
            }),
            None => default,
        }
    }

    /// Comma-separated list of f64 (threshold sweeps).
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().expect("bad float list"))
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_styles() {
        // note: positionals come before bare boolean flags — a bare flag
        // followed by a non-flag token consumes it as its value
        let a = mk(&["serve", "x", "--n", "5", "--delta=0.1", "--verbose"]);
        assert_eq!(a.positional(0), Some("serve"));
        assert_eq!(a.positional(1), Some("x"));
        assert_eq!(a.usize_or("n", 0), 5);
        assert_eq!(a.f64_or("delta", 0.0), 0.1);
        assert!(a.bool_or("verbose", false));
        assert!(!a.has("missing"));
    }

    #[test]
    fn bare_flag_consumes_following_value() {
        let a = mk(&["--verbose", "x"]);
        assert_eq!(a.str_opt("verbose"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = mk(&["--x=-3.5"]);
        assert_eq!(a.f64_or("x", 0.0), -3.5);
    }

    #[test]
    fn float_list() {
        let a = mk(&["--deltas", "0.5,0.25, 0.125"]);
        assert_eq!(a.f64_list("deltas", &[]), vec![0.5, 0.25, 0.125]);
    }
}
