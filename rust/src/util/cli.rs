//! Tiny argument-parsing substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and a generated usage string. On top of the raw
//! [`Args`] map sit the typed `serve` subcommands: [`ServeMode`] selects
//! `serve single | cluster | blackbox` (legacy spellings — a bare
//! `serve` and the old `--blackbox` flag — keep working unchanged), and
//! [`ServeArgs`] is the shared parse of every serve mode's common flags
//! with per-mode defaults and cluster extras (`--replicas`,
//! `--migrate`). Flag documentation lives in [`FlagSpec`] tables the
//! usage string is generated from, so the help text cannot drift from
//! the accepted flags.

use std::collections::BTreeMap;

use anyhow::Result;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.flags.get(key).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse `{v}`"))
        })
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_opt(&self, key: &str) -> Option<f64> {
        self.flags.get(key).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse `{v}`"))
        })
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.parse_or(key, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                panic!("--{key}: cannot parse `{v}`")
            }),
            None => default,
        }
    }

    /// Comma-separated list of f64 (threshold sweeps).
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().expect("bad float list"))
                .collect(),
            None => default.to_vec(),
        }
    }
}

/// Which arrival process an open-loop driver paces (DESIGN.md §3.11).
/// Parsed from the shared `--arrivals` flag; the stream itself is built
/// by `coordinator::workload::build_arrivals` from `(spec, rate, seed)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArrivalSpec {
    /// Memoryless arrivals at `--rate` (the default; the legacy `--rate`
    /// spelling alone means exactly this, unchanged).
    Poisson,
    /// Two-state MMPP: on/off bursts around the same mean rate.
    Burst,
    /// Sinusoid-modulated thinning: peaks at 2x, troughs near zero.
    Diurnal,
    /// Replay recorded timestamps from a file, cycled and rescaled to
    /// `--rate` when one is given.
    Trace(String),
}

impl ArrivalSpec {
    /// Parse an `--arrivals` value: `poisson|burst|diurnal|trace:PATH`.
    pub fn parse(s: &str) -> Result<ArrivalSpec> {
        if let Some(path) = s.strip_prefix("trace:") {
            anyhow::ensure!(!path.is_empty(), "--arrivals trace: needs a file path");
            return Ok(ArrivalSpec::Trace(path.to_string()));
        }
        match s {
            "poisson" => Ok(ArrivalSpec::Poisson),
            "burst" => Ok(ArrivalSpec::Burst),
            "diurnal" => Ok(ArrivalSpec::Diurnal),
            other => anyhow::bail!(
                "unknown --arrivals `{other}` (poisson|burst|diurnal|trace:PATH)"
            ),
        }
    }

    /// The shared `--arrivals` parse used by `serve single|cluster|
    /// blackbox` and `repro soak`. Absent flag = Poisson, so every
    /// legacy `--rate R` invocation parses to exactly what it always
    /// meant.
    pub fn from_args(args: &Args) -> Result<ArrivalSpec> {
        ArrivalSpec::parse(args.str_or("arrivals", "poisson"))
    }
}

/// Which serving engine `serve` drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// One batcher (the PR 3–5 engine). The legacy bare `serve` spelling.
    Single,
    /// N replicas behind the EAT-aware router (`coordinator/cluster.rs`).
    Cluster,
    /// Proxy-monitored remote streams. The legacy `--blackbox` flag
    /// spelling still selects this.
    Blackbox,
}

impl ServeMode {
    /// Parse the mode word after `serve`. No mode word keeps the legacy
    /// spellings intact: bare `serve` is `single`, `serve --blackbox`
    /// is `blackbox`.
    pub fn from_args(args: &Args) -> Result<ServeMode> {
        match args.positional(1) {
            Some("single") => Ok(ServeMode::Single),
            Some("cluster") => Ok(ServeMode::Cluster),
            Some("blackbox") => Ok(ServeMode::Blackbox),
            Some(other) => {
                anyhow::bail!("unknown serve mode `{other}` (single|cluster|blackbox)")
            }
            None => Ok(if args.has("blackbox") {
                ServeMode::Blackbox
            } else {
                ServeMode::Single
            }),
        }
    }
}

/// The flags every `serve` mode shares, parsed once with per-mode
/// defaults, plus the cluster extras. Mode-specific knobs that touch
/// model config (alpha/delta/sched/kv) stay on the raw [`Args`] — this
/// struct owns the workload shape and output plumbing.
#[derive(Debug)]
pub struct ServeArgs {
    pub mode: ServeMode,
    pub dataset: String,
    pub requests: usize,
    pub slots: usize,
    /// Open-loop arrival rate (req/s); 0 = submit all upfront.
    pub rate: f64,
    /// Arrival process shape (`--arrivals`, default Poisson).
    pub arrivals: ArrivalSpec,
    /// Tenant count for multi-tenant admission; arrivals are assigned
    /// round-robin. 1 (the default) is the single-tenant legacy path.
    pub tenants: u32,
    pub virtual_clock: bool,
    pub sequential: bool,
    pub metrics_json: Option<String>,
    /// Cluster: engine replica count.
    pub replicas: usize,
    /// Cluster: migrate waiters between skewed replicas.
    pub migrate: bool,
    /// Cluster: `eat` (least distance-to-exit pressure) or `rr`.
    pub route: String,
    /// Cluster: write each replica's ServeMetrics to `PREFIX.<id>.json`
    /// (the CI `cluster(N=1) ≡ single` equivalence diff).
    pub replica_metrics_json: Option<String>,
}

impl ServeArgs {
    pub fn parse(args: &Args) -> Result<ServeArgs> {
        let mode = ServeMode::from_args(args)?;
        let (dataset_default, requests_default) = match mode {
            ServeMode::Blackbox => ("synth-aime", 8),
            ServeMode::Single | ServeMode::Cluster => ("synth-math500-small", 16),
        };
        let tenants = args.usize_or("tenants", 1);
        anyhow::ensure!(tenants >= 1, "--tenants must be at least 1");
        Ok(ServeArgs {
            mode,
            dataset: args.str_or("dataset", dataset_default).to_string(),
            requests: args.usize_or("requests", requests_default),
            slots: args.usize_or("slots", 4),
            rate: args.f64_or("rate", 0.0),
            arrivals: ArrivalSpec::from_args(args)?,
            tenants: tenants as u32,
            virtual_clock: args.has("virtual"),
            sequential: args.has("sequential"),
            metrics_json: args.str_opt("metrics-json").map(str::to_string),
            replicas: args.usize_or("replicas", 2),
            migrate: args.bool_or("migrate", false),
            route: args.str_or("route", "eat").to_string(),
            replica_metrics_json: args.str_opt("replica-metrics-json").map(str::to_string),
        })
    }
}

/// One documented flag for the generated usage string.
pub struct FlagSpec {
    /// Spelling with value placeholder, e.g. `--dataset D`.
    pub flag: &'static str,
    pub help: &'static str,
}

/// Flags every `serve` mode accepts ([`ServeArgs`] + model config).
pub const SERVE_SHARED_FLAGS: &[FlagSpec] = &[
    FlagSpec { flag: "--dataset D", help: "workload dataset (mode-specific default)" },
    FlagSpec { flag: "--requests N", help: "requests to serve (default 16; blackbox 8)" },
    FlagSpec { flag: "--slots S", help: "KV lanes per engine (default 4)" },
    FlagSpec { flag: "--rate R", help: "open-loop arrival req/s; 0 = submit all upfront" },
    FlagSpec { flag: "--arrivals A", help: "arrival process: poisson|burst|diurnal|trace:PATH (default poisson)" },
    FlagSpec { flag: "--virtual", help: "virtual clock: the run is a pure function of --seed" },
    FlagSpec { flag: "--sequential", help: "disable fused batch decode (A/B determinism checks)" },
    FlagSpec { flag: "--metrics-json FILE", help: "write the metrics snapshot as JSON" },
    FlagSpec { flag: "--seed K", help: "workload + RNG seed (default 0)" },
];

/// `serve single` / `serve cluster` engine flags.
pub const SERVE_ENGINE_FLAGS: &[FlagSpec] = &[
    FlagSpec { flag: "--policy NAME", help: "exit policy: eat, token, eat-stall, ua, confidence, path-dev, seq-entropy, cum-entropy, consistency, ensemble (default eat)" },
    FlagSpec { flag: "--sched fifo|eat", help: "scheduler mode (default fifo)" },
    FlagSpec { flag: "--deadline S", help: "SLO deadline seconds (default 60)" },
    FlagSpec { flag: "--proxy", help: "proxy-monitored (black-box) probes" },
    FlagSpec { flag: "--kv-store paged|mono", help: "KV store (default paged)" },
    FlagSpec { flag: "--page-size P", help: "tokens per KV page (default 16)" },
    FlagSpec { flag: "--kv-pages N", help: "device/host page budget (default slots*reserve)" },
    FlagSpec { flag: "--tenants N", help: "tenants sharing the engine, DRR-fair (default 1)" },
    FlagSpec { flag: "--shed none|reject|eat", help: "overload control: reject at SLO, or EAT-shed nearest-to-exit (default none)" },
];

/// `serve cluster` extras.
pub const SERVE_CLUSTER_FLAGS: &[FlagSpec] = &[
    FlagSpec { flag: "--replicas N", help: "engine replicas (default 2)" },
    FlagSpec { flag: "--route eat|rr", help: "placement: EAT distance-to-exit or round-robin" },
    FlagSpec { flag: "--migrate", help: "migrate waiters between skewed replicas (page handoff)" },
    FlagSpec { flag: "--replica-metrics-json P", help: "write per-replica metrics to P.<id>.json" },
];

/// `serve blackbox` extras.
pub const SERVE_BLACKBOX_FLAGS: &[FlagSpec] = &[
    FlagSpec { flag: "--chunk C", help: "streamed tokens per chunk (default 12)" },
    FlagSpec { flag: "--base-ms B", help: "remote latency base (default model)" },
    FlagSpec { flag: "--tok-ms T", help: "remote latency per token" },
    FlagSpec { flag: "--jitter J", help: "remote latency jitter fraction" },
];

/// `soak` flags (DESIGN.md §3.10). The soak always runs on virtual
/// time; `--virtual` is accepted for symmetry with `serve`.
pub const SOAK_FLAGS: &[FlagSpec] = &[
    FlagSpec { flag: "--sessions N", help: "sessions to push through (default 100000)" },
    FlagSpec { flag: "--rate R", help: "arrival rate, sessions/s (default 500)" },
    FlagSpec { flag: "--arrivals A", help: "arrival process: poisson|burst|diurnal|trace:PATH (default poisson)" },
    FlagSpec { flag: "--overload F", help: "override --rate to F x estimated service capacity" },
    FlagSpec { flag: "--slo S", help: "per-session SLO seconds for goodput/shed accounting" },
    FlagSpec { flag: "--shed none|reject|eat", help: "overload control under full residency (default none)" },
    FlagSpec { flag: "--slots S", help: "concurrent resident sessions (default 256)" },
    FlagSpec { flag: "--seed K", help: "demand + arrival seed (default 0)" },
    FlagSpec { flag: "--mem-mb M", help: "hard accounted-memory ceiling; breach fails the run" },
    FlagSpec { flag: "--summary-cap C", help: "latency/wait reservoir bound (default 65536)" },
    FlagSpec { flag: "--driver", help: "pre-wheel tick-scan reference core (bench baseline)" },
    FlagSpec { flag: "--metrics-json FILE", help: "write the deterministic soak report as JSON" },
    FlagSpec { flag: "--virtual", help: "accepted no-op: the soak is always virtual-time" },
];

/// Render one flag table, aligned, for the usage string.
pub fn render_flags(indent: &str, specs: &[FlagSpec]) -> String {
    let width = specs.iter().map(|s| s.flag.len()).max().unwrap_or(0);
    specs
        .iter()
        .map(|s| format!("{indent}{:<width$}  {}\n", s.flag, s.help))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_styles() {
        // note: positionals come before bare boolean flags — a bare flag
        // followed by a non-flag token consumes it as its value
        let a = mk(&["serve", "x", "--n", "5", "--delta=0.1", "--verbose"]);
        assert_eq!(a.positional(0), Some("serve"));
        assert_eq!(a.positional(1), Some("x"));
        assert_eq!(a.usize_or("n", 0), 5);
        assert_eq!(a.f64_or("delta", 0.0), 0.1);
        assert!(a.bool_or("verbose", false));
        assert!(!a.has("missing"));
    }

    #[test]
    fn bare_flag_consumes_following_value() {
        let a = mk(&["--verbose", "x"]);
        assert_eq!(a.str_opt("verbose"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = mk(&["--x=-3.5"]);
        assert_eq!(a.f64_or("x", 0.0), -3.5);
    }

    #[test]
    fn float_list() {
        let a = mk(&["--deltas", "0.5,0.25, 0.125"]);
        assert_eq!(a.f64_list("deltas", &[]), vec![0.5, 0.25, 0.125]);
    }

    #[test]
    fn serve_mode_words_and_legacy_spellings() {
        // typed subcommands
        assert_eq!(
            ServeMode::from_args(&mk(&["serve", "single"])).unwrap(),
            ServeMode::Single
        );
        assert_eq!(
            ServeMode::from_args(&mk(&["serve", "cluster", "--replicas", "3"])).unwrap(),
            ServeMode::Cluster
        );
        assert_eq!(
            ServeMode::from_args(&mk(&["serve", "blackbox"])).unwrap(),
            ServeMode::Blackbox
        );
        // legacy spellings, unchanged behavior
        assert_eq!(
            ServeMode::from_args(&mk(&["serve", "--requests", "24"])).unwrap(),
            ServeMode::Single
        );
        assert_eq!(
            ServeMode::from_args(&mk(&["serve", "--blackbox", "--chunk", "12"])).unwrap(),
            ServeMode::Blackbox
        );
        assert!(ServeMode::from_args(&mk(&["serve", "fleet"])).is_err());
    }

    #[test]
    fn serve_args_mode_defaults_and_cluster_extras() {
        let single = ServeArgs::parse(&mk(&["serve", "--virtual"])).unwrap();
        assert_eq!(single.dataset, "synth-math500-small");
        assert_eq!(single.requests, 16);
        assert!(single.virtual_clock);
        assert!(!single.migrate);

        let bb = ServeArgs::parse(&mk(&["serve", "--blackbox"])).unwrap();
        assert_eq!(bb.dataset, "synth-aime");
        assert_eq!(bb.requests, 8);

        let cl = ServeArgs::parse(&mk(&[
            "serve",
            "cluster",
            "--replicas",
            "4",
            "--migrate",
            "--route",
            "rr",
            "--replica-metrics-json",
            "out/replica",
        ]))
        .unwrap();
        assert_eq!(cl.mode, ServeMode::Cluster);
        assert_eq!(cl.replicas, 4);
        assert!(cl.migrate);
        assert_eq!(cl.route, "rr");
        assert_eq!(cl.replica_metrics_json.as_deref(), Some("out/replica"));
    }

    #[test]
    fn usage_is_generated_from_the_flag_tables() {
        let s = render_flags("  ", SERVE_CLUSTER_FLAGS);
        assert!(s.contains("--replicas N"));
        assert!(s.contains("--migrate"));
        for spec in SERVE_SHARED_FLAGS {
            assert!(render_flags("", SERVE_SHARED_FLAGS).contains(spec.flag));
        }
    }

    #[test]
    fn arrival_spec_parses_the_zoo() {
        assert_eq!(ArrivalSpec::parse("poisson").unwrap(), ArrivalSpec::Poisson);
        assert_eq!(ArrivalSpec::parse("burst").unwrap(), ArrivalSpec::Burst);
        assert_eq!(ArrivalSpec::parse("diurnal").unwrap(), ArrivalSpec::Diurnal);
        assert_eq!(
            ArrivalSpec::parse("trace:/tmp/a.json").unwrap(),
            ArrivalSpec::Trace("/tmp/a.json".to_string())
        );
        assert!(ArrivalSpec::parse("trace:").is_err());
        assert!(ArrivalSpec::parse("selfsimilar").is_err());
    }

    #[test]
    fn legacy_rate_spelling_still_means_poisson() {
        // The pre-zoo CLI contract, pinned: `--rate R` with no
        // `--arrivals` parses to Poisson at R, byte-for-byte the same
        // ServeArgs as before the ArrivalSpec refactor.
        let a = ServeArgs::parse(&mk(&["serve", "--rate", "50", "--virtual"])).unwrap();
        assert_eq!(a.rate, 50.0);
        assert_eq!(a.arrivals, ArrivalSpec::Poisson);
        assert_eq!(a.tenants, 1);

        let b = ServeArgs::parse(&mk(&[
            "serve", "cluster", "--rate", "50", "--arrivals", "burst", "--tenants", "8",
        ]))
        .unwrap();
        assert_eq!(b.arrivals, ArrivalSpec::Burst);
        assert_eq!(b.tenants, 8);
        assert!(ServeArgs::parse(&mk(&["serve", "--tenants", "0"])).is_err());
        assert!(ServeArgs::parse(&mk(&["serve", "--arrivals", "bogus"])).is_err());
    }

    /// First token of a spec's spelling: `--rate R` -> `--rate`.
    fn flag_name(spec: &FlagSpec) -> &str {
        spec.flag.split_whitespace().next().unwrap()
    }

    #[test]
    fn flag_tables_cover_every_parsed_flag_and_never_collide() {
        // The usage text in main.rs is rendered straight from these
        // tables, so "tables cover the parser" == "usage covers the
        // parser": any flag a subcommand reads must appear in its
        // tables, or the generated help has drifted.
        let serve_single: Vec<&FlagSpec> = SERVE_SHARED_FLAGS
            .iter()
            .chain(SERVE_ENGINE_FLAGS)
            .collect();
        let serve_cluster: Vec<&FlagSpec> = serve_single
            .iter()
            .copied()
            .chain(SERVE_CLUSTER_FLAGS)
            .collect();
        let serve_blackbox: Vec<&FlagSpec> = SERVE_SHARED_FLAGS
            .iter()
            .chain(SERVE_BLACKBOX_FLAGS)
            .collect();

        // Flags each parser actually reads (ServeArgs::parse + the
        // model-config reads in main.rs).
        let single_reads = [
            "--dataset", "--requests", "--slots", "--rate", "--arrivals", "--virtual",
            "--sequential", "--metrics-json", "--seed", "--policy", "--sched", "--deadline",
            "--proxy", "--kv-store", "--page-size", "--kv-pages", "--tenants", "--shed",
        ];
        let cluster_reads = [
            "--replicas", "--route", "--migrate", "--replica-metrics-json",
        ];
        let blackbox_reads = [
            "--dataset", "--requests", "--slots", "--rate", "--arrivals", "--virtual",
            "--sequential", "--metrics-json", "--seed", "--chunk", "--base-ms", "--tok-ms",
            "--jitter",
        ];
        let soak_reads = [
            "--sessions", "--rate", "--arrivals", "--overload", "--slo", "--shed", "--slots",
            "--seed", "--mem-mb", "--summary-cap", "--driver", "--metrics-json", "--virtual",
        ];

        let covers = |table: &[&FlagSpec], reads: &[&str], cmd: &str| {
            for want in reads {
                assert!(
                    table.iter().any(|s| flag_name(s) == *want),
                    "{cmd} parses {want} but its flag tables (and so its usage text) omit it"
                );
            }
        };
        covers(&serve_single, &single_reads, "serve single");
        covers(&serve_cluster, &single_reads, "serve cluster");
        covers(&serve_cluster, &cluster_reads, "serve cluster");
        covers(&serve_blackbox, &blackbox_reads, "serve blackbox");
        let soak: Vec<&FlagSpec> = SOAK_FLAGS.iter().collect();
        covers(&soak, &soak_reads, "soak");

        // and no combined table documents the same flag twice
        for (table, cmd) in [
            (&serve_cluster, "serve cluster"),
            (&serve_blackbox, "serve blackbox"),
            (&soak, "soak"),
        ] {
            let mut names: Vec<&str> = table.iter().map(|s| flag_name(s)).collect();
            names.sort_unstable();
            let before = names.len();
            names.dedup();
            assert_eq!(before, names.len(), "{cmd} documents a flag twice");
        }
    }

    #[test]
    fn rendered_usage_carries_every_flag_and_its_help() {
        // main.rs builds its usage text by rendering these tables, so
        // this pins the other half of the sync: rendering drops nothing
        for table in [
            SERVE_SHARED_FLAGS,
            SERVE_ENGINE_FLAGS,
            SERVE_CLUSTER_FLAGS,
            SERVE_BLACKBOX_FLAGS,
            SOAK_FLAGS,
        ] {
            let rendered = render_flags("  ", table);
            for spec in table {
                assert!(rendered.contains(spec.flag), "usage lost {}", spec.flag);
                assert!(rendered.contains(spec.help), "usage lost help for {}", spec.flag);
            }
        }
    }
}
