//! Injectable time source (DESIGN.md §3.4): a wall clock for live
//! serving, a virtual clock for deterministic simulation.
//!
//! The batcher, the serving metrics and the Poisson workload driver all
//! read time through a shared [`Clock`] handle instead of calling
//! `std::time::Instant` directly. Under a virtual clock time only moves
//! when the driver advances it — a fixed `tick_dt` per scheduling tick,
//! plus a jump to the next arrival when the batcher idles — so an entire
//! serve run (arrivals, admission order, preemption decisions, latency
//! percentiles) is a pure function of the seed. Two same-seed runs emit
//! byte-identical metrics JSON; `tests/scheduler_sim.rs` and the CI
//! determinism step both pin this down.

use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

/// A shared time handle. Cloning yields another handle onto the *same*
/// clock: virtual handles share their timeline through an `Rc`, wall
/// handles share their epoch.
#[derive(Debug, Clone)]
pub enum Clock {
    /// Real time, measured from the moment the handle was created.
    Wall(Instant),
    /// Simulated time in seconds, advanced explicitly by the driver.
    Virtual(Rc<Cell<f64>>),
}

impl Clock {
    /// A wall clock whose epoch is "now".
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    /// A fresh virtual clock at t = 0.
    pub fn virt() -> Clock {
        Clock::Virtual(Rc::new(Cell::new(0.0)))
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }

    /// Seconds since the clock's epoch.
    pub fn now(&self) -> f64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_secs_f64(),
            Clock::Virtual(t) => t.get(),
        }
    }

    /// Advance a virtual clock by `dt` seconds (visible through every
    /// handle sharing the timeline). No-op on a wall clock — real time
    /// advances itself — and for non-positive `dt`.
    pub fn advance(&self, dt: f64) {
        if let Clock::Virtual(t) = self {
            if dt > 0.0 {
                t.set(t.get() + dt);
            }
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_time_only_moves_when_advanced() {
        let c = Clock::virt();
        assert_eq!(c.now(), 0.0);
        c.advance(0.5);
        c.advance(0.25);
        assert!((c.now() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cloned_handles_share_the_timeline() {
        let a = Clock::virt();
        let b = a.clone();
        a.advance(1.0);
        assert_eq!(b.now(), a.now());
        b.advance(2.0);
        assert!((a.now() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn negative_advance_is_ignored() {
        let c = Clock::virt();
        c.advance(1.0);
        c.advance(-5.0);
        assert!((c.now() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wall_clock_is_monotone_and_ignores_advance() {
        let c = Clock::wall();
        assert!(!c.is_virtual());
        let t1 = c.now();
        c.advance(1000.0); // no-op
        let t2 = c.now();
        assert!(t2 >= t1);
        assert!(t2 < 100.0, "wall epoch should be handle creation");
    }
}
