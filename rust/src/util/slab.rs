//! Generational slab arena (DESIGN.md §3.10): dense, index-stable
//! storage for session state. Sessions churn constantly in a soak run
//! (a million arrivals against a few hundred resident at a time);
//! boxing each one scatters the heap and keying them by id in a map
//! costs a lookup per event. A slab keeps every live session in one
//! contiguous allocation, hands out O(1) generational keys, and reuses
//! freed slots LIFO — so steady-state insert/remove allocates nothing
//! and the arena's high-water footprint is `peak_live × slot_size`,
//! which is exactly the bytes/session number the soak reports.
//!
//! Generations make dangling keys safe *and detectable*: the event
//! wheel holds keys to sessions that may complete, migrate or stall
//! out before their timer fires, and a stale key simply misses
//! (`get`/`remove` return `None`) instead of aliasing whatever reused
//! the slot. Iteration is in slot-index order — deterministic, never
//! hash order.

/// Key into a [`Slab`]: slot index plus the generation it was minted
/// for. A key outlives its entry harmlessly — every access checks the
/// generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GenKey {
    index: u32,
    gen: u32,
}

impl GenKey {
    /// Slot index (stable while the entry lives; reused after removal).
    pub fn index(&self) -> u32 {
        self.index
    }

    pub fn gen(&self) -> u32 {
        self.gen
    }
}

struct Slot<T> {
    /// Bumped on every removal, so old keys to this slot miss.
    gen: u32,
    val: Option<T>,
}

/// Generational slab arena; see the module docs.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    /// Free slot indices, reused LIFO (cache-warm first).
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Slab<T> {
        Slab {
            slots: Vec::with_capacity(cap),
            free: Vec::new(),
            len: 0,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slots ever allocated (the high-water mark of concurrent entries).
    pub fn capacity_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn insert(&mut self, val: T) -> GenKey {
        self.len += 1;
        if let Some(index) = self.free.pop() {
            let slot = &mut self.slots[index as usize];
            debug_assert!(slot.val.is_none(), "free-listed slot must be vacant");
            slot.val = Some(val);
            return GenKey {
                index,
                gen: slot.gen,
            };
        }
        let index = u32::try_from(self.slots.len()).expect("slab capped at u32 slots");
        self.slots.push(Slot { gen: 0, val: Some(val) });
        GenKey { index, gen: 0 }
    }

    fn slot(&self, key: GenKey) -> Option<&Slot<T>> {
        self.slots
            .get(key.index as usize)
            .filter(|s| s.gen == key.gen && s.val.is_some())
    }

    pub fn contains(&self, key: GenKey) -> bool {
        self.slot(key).is_some()
    }

    pub fn get(&self, key: GenKey) -> Option<&T> {
        self.slot(key).and_then(|s| s.val.as_ref())
    }

    pub fn get_mut(&mut self, key: GenKey) -> Option<&mut T> {
        let slot = self
            .slots
            .get_mut(key.index as usize)
            .filter(|s| s.gen == key.gen && s.val.is_some())?;
        slot.val.as_mut()
    }

    /// Remove and return the entry; stale keys miss with `None`. The
    /// slot's generation bumps so every outstanding key to it dies.
    pub fn remove(&mut self, key: GenKey) -> Option<T> {
        let slot = self
            .slots
            .get_mut(key.index as usize)
            .filter(|s| s.gen == key.gen && s.val.is_some())?;
        let val = slot.val.take();
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(key.index);
        self.len -= 1;
        val
    }

    /// Live entries in slot-index order (deterministic).
    pub fn iter(&self) -> impl Iterator<Item = (GenKey, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| {
            s.val.as_ref().map(|v| {
                (
                    GenKey {
                        index: i as u32,
                        gen: s.gen,
                    },
                    v,
                )
            })
        })
    }

    /// Approximate heap footprint (capacity-based): the arena backbone
    /// plus the free list — the denominator-side input to the soak's
    /// bytes/session accounting.
    pub fn approx_bytes(&self) -> usize {
        self.slots.capacity() * std::mem::size_of::<Slot<T>>()
            + self.free.capacity() * std::mem::size_of::<u32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_round_trip() {
        let mut s: Slab<String> = Slab::new();
        let a = s.insert("a".into());
        let b = s.insert("b".into());
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a).unwrap(), "a");
        assert_eq!(s.get_mut(b).map(|v| v.push('!')), Some(()));
        assert_eq!(s.remove(b).unwrap(), "b!");
        assert_eq!(s.len(), 1);
        assert!(s.get(b).is_none());
        assert_eq!(s.remove(a).unwrap(), "a");
        assert!(s.is_empty());
    }

    #[test]
    fn stale_keys_miss_after_slot_reuse() {
        let mut s: Slab<u64> = Slab::new();
        let k1 = s.insert(1);
        assert_eq!(s.remove(k1), Some(1));
        let k2 = s.insert(2);
        // LIFO reuse: same slot index, new generation
        assert_eq!(k2.index(), k1.index());
        assert_ne!(k2.gen(), k1.gen());
        assert!(!s.contains(k1));
        assert_eq!(s.remove(k1), None, "stale key must miss, not alias");
        assert_eq!(s.get(k2), Some(&2));
    }

    #[test]
    fn double_remove_is_a_miss() {
        let mut s: Slab<u8> = Slab::new();
        let k = s.insert(7);
        assert_eq!(s.remove(k), Some(7));
        assert_eq!(s.remove(k), None);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn capacity_tracks_peak_not_total_churn() {
        let mut s: Slab<u64> = Slab::new();
        // 1000 sequential insert/remove cycles at ≤ 2 live entries must
        // not grow the arena past 2 slots
        let mut held = s.insert(0);
        for i in 1..1000u64 {
            let k = s.insert(i);
            s.remove(held);
            held = k;
        }
        assert_eq!(s.len(), 1);
        assert_eq!(s.capacity_slots(), 2);
    }

    #[test]
    fn iteration_is_index_ordered_and_skips_holes() {
        let mut s: Slab<u32> = Slab::new();
        let keys: Vec<GenKey> = (0..5).map(|i| s.insert(i * 10)).collect();
        s.remove(keys[1]);
        s.remove(keys[3]);
        let got: Vec<u32> = s.iter().map(|(_, &v)| v).collect();
        assert_eq!(got, vec![0, 20, 40]);
        for (k, &v) in s.iter() {
            assert_eq!(s.get(k), Some(&v));
        }
    }

    #[test]
    fn bytes_reflect_backbone_capacity() {
        let mut s: Slab<[u64; 8]> = Slab::new();
        let empty = s.approx_bytes();
        for _ in 0..100 {
            s.insert([0; 8]);
        }
        assert!(s.approx_bytes() >= empty + 100 * std::mem::size_of::<[u64; 8]>());
    }
}
