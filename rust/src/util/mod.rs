//! Hand-rolled substrates (DESIGN.md §1): the offline crate registry only
//! carries `anyhow` (plus the optional, feature-gated `xla`), so JSON,
//! CLI parsing, RNG, statistics and the bench harness are implemented
//! here.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod wheel;
