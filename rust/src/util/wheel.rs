//! Virtual-time event wheel (DESIGN.md §3.10): one calendar queue that
//! owns *every* future event in a simulation — open-loop arrivals,
//! black-box chunk deliveries, suspension aging, soak completion and
//! stall timers — so the hot path asks "what fires next" in O(1)
//! amortized instead of rescanning per-component sorted vectors.
//!
//! Structure: a ring of `nbuckets` time buckets of `width` virtual
//! seconds each, anchored at `origin`. An event lands in the bucket its
//! timestamp falls into; events past the ring's horizon wait in an
//! overflow list and are re-filed when the ring rotates past them. Each
//! bucket is a tiny binary min-heap over the **total** event order
//!
//! ```text
//! (virtual_time by f64::total_cmp, lane, seq)
//! ```
//!
//! which is exactly the `(virtual_time, replica_id, seq)` order the
//! pre-wheel sorted-vec/heap schedulers dequeued in — the differential
//! proptest in `rust/tests/proptests.rs` holds the wheel to it against
//! a reference [`std::collections::BinaryHeap`] on random event sets.
//!
//! Determinism: bucket choice, heap sift order and overflow re-filing
//! are pure functions of the (key, insertion-order) stream — no
//! wall-clock reads, no hashing — so two same-seed simulation runs pop
//! byte-identical event sequences.
//!
//! Cost model: `schedule` is O(log bucket_occupancy) (buckets hold few
//! events, so effectively O(1)); `pop`/`peek` amortize the cursor walk
//! over rotations; a fully drained wheel re-anchors at the next
//! scheduled event, making long idle gaps one O(1) jump instead of a
//! bucket crawl.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Total event order: virtual time (total_cmp, so NaN cannot panic the
/// scheduler), then lane (replica / slot id), then submission seq.
#[derive(Debug, Clone, Copy)]
pub struct EventKey {
    pub time: f64,
    pub lane: u32,
    pub seq: u64,
}

impl EventKey {
    pub fn new(time: f64, lane: u32, seq: u64) -> EventKey {
        EventKey { time, lane, seq }
    }
}

impl PartialEq for EventKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for EventKey {}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.lane.cmp(&other.lane))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Heap entry: ordered by key only, payload rides along.
struct Entry<V> {
    key: EventKey,
    val: V,
}

impl<V> PartialEq for Entry<V> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<V> Eq for Entry<V> {}

impl<V> PartialOrd for Entry<V> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<V> Ord for Entry<V> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.cmp(&other.key)
    }
}

type Bucket<V> = BinaryHeap<Reverse<Entry<V>>>;

/// Hierarchical (ring + overflow) virtual-time calendar queue. See the
/// module docs for the ordering and determinism contracts.
pub struct EventWheel<V> {
    buckets: Vec<Bucket<V>>,
    /// Events at or past the ring horizon, unsorted; re-filed on rotate.
    overflow: Vec<Entry<V>>,
    /// Virtual time the ring starts at; bucket `i` covers
    /// `[origin + i·width, origin + (i+1)·width)`.
    origin: f64,
    /// Current consumption bucket; events for earlier buckets (late
    /// schedules) clamp here so they still pop in key order.
    cursor: usize,
    width: f64,
    len: usize,
}

/// Default ring width: one [`crate::coordinator::DEFAULT_TICK_DT`]-sized
/// bucket granularity over a ~10-virtual-second horizon.
const DEFAULT_BUCKETS: usize = 1024;

impl<V> EventWheel<V> {
    /// Wheel with `width` virtual seconds per bucket and the default
    /// ring size. `width` must be positive and finite.
    pub fn new(width: f64) -> EventWheel<V> {
        EventWheel::with_geometry(width, DEFAULT_BUCKETS)
    }

    pub fn with_geometry(width: f64, nbuckets: usize) -> EventWheel<V> {
        assert!(width.is_finite() && width > 0.0, "bucket width must be positive");
        assert!(nbuckets >= 1, "wheel needs at least one bucket");
        EventWheel {
            buckets: (0..nbuckets).map(|_| BinaryHeap::new()).collect(),
            overflow: Vec::new(),
            origin: 0.0,
            cursor: 0,
            width,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn horizon_buckets(&self) -> f64 {
        self.buckets.len() as f64
    }

    /// File one entry into its ring bucket (clamped to the cursor for
    /// past timestamps) or the overflow list.
    fn file(&mut self, e: Entry<V>) {
        let d = (e.key.time - self.origin) / self.width;
        if d >= self.horizon_buckets() {
            self.overflow.push(e);
            return;
        }
        // `as usize` saturates negative/NaN to 0; the max() keeps late
        // schedules poppable (they sort first inside the cursor bucket)
        let idx = (d as usize).min(self.buckets.len() - 1).max(self.cursor);
        self.buckets[idx].push(Reverse(e));
    }

    /// Schedule an event. Timestamps already in the past are legal: they
    /// fire on the next pop, ahead of anything later-keyed.
    pub fn schedule(&mut self, key: EventKey, val: V) {
        if self.len == 0 {
            // drained wheel: re-anchor at the new event so a long idle
            // gap is one O(1) jump, not a bucket crawl
            self.origin = if key.time.is_finite() { key.time } else { 0.0 };
            self.cursor = 0;
        }
        self.len += 1;
        self.file(Entry { key, val });
    }

    /// Convenience: schedule by raw key parts.
    pub fn schedule_at(&mut self, time: f64, lane: u32, seq: u64, val: V) {
        self.schedule(EventKey::new(time, lane, seq), val);
    }

    /// Advance the cursor to the next non-empty bucket, rotating the
    /// ring (and re-filing overflow) as needed. Returns false when the
    /// wheel is empty.
    fn advance_to_nonempty(&mut self) -> bool {
        if self.len == 0 {
            return false;
        }
        loop {
            while self.cursor < self.buckets.len() {
                if !self.buckets[self.cursor].is_empty() {
                    return true;
                }
                self.cursor += 1;
            }
            // ring exhausted: rotate the horizon forward
            self.cursor = 0;
            self.origin += self.horizon_buckets() * self.width;
            if self.buckets.iter().all(|b| b.is_empty()) && !self.overflow.is_empty() {
                // everything pending is far future: jump the origin to
                // the earliest overflow event instead of rotating
                // through empty horizons one by one
                let min_t = self
                    .overflow
                    .iter()
                    .map(|e| e.key.time)
                    .fold(f64::INFINITY, |a, t| if t.total_cmp(&a).is_lt() { t } else { a });
                if min_t.is_finite() {
                    if min_t > self.origin {
                        self.origin = min_t;
                    }
                } else {
                    // every pending event sits at +inf — nothing in the
                    // sims schedules that, but pop() must terminate
                    // anyway. They are the global maximum, so the final
                    // bucket (heap-ordered by lane/seq among equal
                    // times) serves them in key order.
                    let last = self.buckets.len() - 1;
                    for e in self.overflow.drain(..) {
                        self.buckets[last].push(Reverse(e));
                    }
                }
            }
            // re-file every overflow event now inside the horizon
            let mut i = 0;
            while i < self.overflow.len() {
                let within =
                    (self.overflow[i].key.time - self.origin) / self.width < self.horizon_buckets();
                if within {
                    let e = self.overflow.swap_remove(i);
                    self.file(e);
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Key of the next event to fire, without removing it.
    pub fn peek(&mut self) -> Option<EventKey> {
        if !self.advance_to_nonempty() {
            return None;
        }
        self.buckets[self.cursor].peek().map(|Reverse(e)| e.key)
    }

    /// Virtual time of the next event (the idle-jump target).
    pub fn peek_time(&mut self) -> Option<f64> {
        self.peek().map(|k| k.time)
    }

    /// Remove and return the next event in `(time, lane, seq)` order.
    pub fn pop(&mut self) -> Option<(EventKey, V)> {
        if !self.advance_to_nonempty() {
            return None;
        }
        let Reverse(e) = self.buckets[self.cursor].pop().expect("bucket is non-empty");
        self.len -= 1;
        Some((e.key, e.val))
    }

    /// Pop every event with `key.time <= now`, in order, into `out`.
    /// Returns the number delivered.
    pub fn pop_due(&mut self, now: f64, out: &mut Vec<(EventKey, V)>) -> usize {
        let mut n = 0;
        while let Some(k) = self.peek() {
            if k.time > now {
                break;
            }
            out.push(self.pop().expect("peeked event exists"));
            n += 1;
        }
        n
    }

    /// Approximate heap footprint (capacity-based), for the soak's
    /// accounted-bytes report.
    pub fn approx_bytes(&self) -> usize {
        let entry = std::mem::size_of::<Entry<V>>();
        let heaps: usize = self.buckets.iter().map(|b| b.capacity() * entry).sum();
        heaps
            + self.overflow.capacity() * entry
            + self.buckets.capacity() * std::mem::size_of::<Bucket<V>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<V>(w: &mut EventWheel<V>) -> Vec<EventKey> {
        let mut out = Vec::new();
        while let Some((k, _)) = w.pop() {
            out.push(k);
        }
        out
    }

    #[test]
    fn pops_in_time_lane_seq_order() {
        let mut w = EventWheel::new(0.01);
        w.schedule_at(2.0, 1, 5, ());
        w.schedule_at(1.0, 3, 9, ());
        w.schedule_at(2.0, 0, 7, ());
        w.schedule_at(2.0, 1, 4, ());
        let ks = drain(&mut w);
        let got: Vec<(f64, u32, u64)> = ks.iter().map(|k| (k.time, k.lane, k.seq)).collect();
        assert_eq!(
            got,
            vec![(1.0, 3, 9), (2.0, 0, 7), (2.0, 1, 4), (2.0, 1, 5)]
        );
        assert!(w.is_empty());
    }

    #[test]
    fn far_future_events_cross_the_horizon() {
        // 4 buckets of 1s: horizon is 4s, so these all live in overflow
        // at least once and must still pop sorted
        let mut w = EventWheel::with_geometry(1.0, 4);
        for (i, t) in [100.0, 3.5, 0.5, 42.0, 7.9, 8.0].iter().enumerate() {
            w.schedule_at(*t, 0, i as u64, ());
        }
        let times: Vec<f64> = drain(&mut w).iter().map(|k| k.time).collect();
        assert_eq!(times, vec![0.5, 3.5, 7.9, 8.0, 42.0, 100.0]);
    }

    #[test]
    fn late_schedules_fire_next() {
        let mut w = EventWheel::new(0.5);
        w.schedule_at(10.0, 0, 0, "later");
        w.schedule_at(10.5, 0, 1, "last");
        assert_eq!(w.pop().unwrap().1, "later");
        // now in the "past" relative to the cursor: must still pop, and
        // ahead of the remaining later event
        w.schedule_at(3.0, 0, 2, "past");
        assert_eq!(w.pop().unwrap().1, "past");
        assert_eq!(w.pop().unwrap().1, "last");
        assert!(w.pop().is_none());
    }

    #[test]
    fn drained_wheel_reanchors_without_crawling() {
        let mut w = EventWheel::with_geometry(0.01, 8);
        w.schedule_at(0.02, 0, 0, ());
        assert!(w.pop().is_some());
        // a gap of ~10^7 bucket widths: must not rotate its way there
        w.schedule_at(123456.0, 0, 1, ());
        assert_eq!(w.peek_time(), Some(123456.0));
        assert_eq!(w.pop().unwrap().0.seq, 1);
    }

    #[test]
    fn pop_due_splits_at_now() {
        let mut w = EventWheel::new(0.25);
        for i in 0..10u64 {
            w.schedule_at(i as f64 * 0.1, 0, i, i);
        }
        let mut due = Vec::new();
        assert_eq!(w.pop_due(0.45, &mut due), 5);
        assert_eq!(due.len(), 5);
        assert!(due.iter().all(|(k, _)| k.time <= 0.45));
        assert_eq!(w.len(), 5);
        assert_eq!(w.peek_time(), Some(0.5));
    }

    #[test]
    fn interleaved_schedule_and_pop_stay_sorted() {
        // deterministic pseudo-random workload without an RNG dep
        let mut w = EventWheel::with_geometry(0.1, 16);
        let mut x: u64 = 0x9E3779B97F4A7C15;
        let mut popped: Vec<EventKey> = Vec::new();
        let mut floor = f64::NEG_INFINITY;
        for i in 0..500u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let t = (x >> 40) as f64 / 1e4; // [0, ~1.7)
            // never schedule before the last popped time, so the full
            // pop sequence must be globally sorted
            w.schedule_at(t.max(floor), (x % 3) as u32, i, ());
            if x % 4 == 0 {
                if let Some((k, _)) = w.pop() {
                    floor = k.time;
                    popped.push(k);
                }
            }
        }
        popped.extend(drain(&mut w));
        assert_eq!(popped.len(), 500);
        // full-key order holds within what was pending together; across
        // schedule-after-pop boundaries only time order is guaranteed
        for p in popped.windows(2) {
            assert!(
                p[0].time.total_cmp(&p[1].time).is_le(),
                "out of order: {:?} then {:?}",
                p[0],
                p[1]
            );
        }
    }

    #[test]
    fn nan_time_cannot_panic_or_wedge() {
        let mut w = EventWheel::new(0.5);
        w.schedule_at(f64::NAN, 0, 0, "nan");
        w.schedule_at(1.0, 0, 1, "one");
        // NaN saturates into the cursor bucket and total_cmp sorts it
        // after +inf inside the heap; both events come out
        let ks = drain(&mut w);
        assert_eq!(ks.len(), 2);
    }

    #[test]
    fn infinite_time_cannot_wedge_the_rotate() {
        // +inf lands in overflow and can never re-file by arithmetic
        // ((inf - origin)/width stays inf); the rotate must serve it
        // from the final bucket instead of spinning forever
        let mut w = EventWheel::with_geometry(0.5, 4);
        w.schedule_at(f64::INFINITY, 1, 1, "inf-b");
        w.schedule_at(1.0, 0, 0, "one");
        w.schedule_at(f64::INFINITY, 0, 0, "inf-a");
        assert_eq!(w.pop().unwrap().1, "one");
        // equal (+inf) times fall back to (lane, seq) order
        assert_eq!(w.pop().unwrap().1, "inf-a");
        assert_eq!(w.pop().unwrap().1, "inf-b");
        assert!(w.pop().is_none());
    }

    #[test]
    fn bytes_accounting_is_capacity_based() {
        let mut w: EventWheel<u64> = EventWheel::with_geometry(0.01, 32);
        let empty = w.approx_bytes();
        for i in 0..1000u64 {
            w.schedule_at(i as f64 * 0.003, 0, i, i);
        }
        assert!(w.approx_bytes() > empty);
    }
}
