//! EAT monitoring: the EMA mean/variance estimator (Alg. 1) and the
//! per-request trajectory records used by the eval harness and figures.

pub mod ema;
pub mod trace;

pub use ema::EmaVar;
pub use trace::{LinePoint, Trace};
