//! EMA mean/variance estimator of the EAT trajectory — the statistical core
//! of the paper's stopping rule (Alg. 1 lines 7–8, Eqs. 7–8):
//!
//!   M_n = (1-a) M_{n-1} + a x_n
//!   V_n = (1-a) V_{n-1} + a (x_n - M_n)^2
//!   V'_n = V_n / (1 - (1-a)^n)        (de-biasing from zero init, line 8)
//!
//! Intuitively V' measures the variance of the signal over roughly the last
//! 1/alpha observations; reasoning halts when V' < delta.

#[derive(Debug, Clone)]
pub struct EmaVar {
    alpha: f64,
    mean: f64,
    var: f64,
    n: u64,
    /// Running bias factor (1-a)^n, maintained by one multiply per
    /// observation instead of a `powi(n)` in every `debiased_var` call
    /// (DESIGN.md §3.8). Branchless — no exponent clamp needed: the
    /// product underflows to exactly 0.0 (denominator 1) long before `n`
    /// could trouble any integer cast. Sequential rounding can differ
    /// from `powi`'s repeated squaring by a few ULPs; the tolerance test
    /// `running_power_tracks_powi_denominator` bounds the drift.
    bias_pow: f64,
}

impl EmaVar {
    pub fn new(alpha: f64) -> EmaVar {
        assert!(
            alpha > 0.0 && alpha < 1.0,
            "EMA timescale must be in (0,1), got {alpha}"
        );
        EmaVar {
            alpha,
            mean: 0.0,
            var: 0.0,
            n: 0,
            bias_pow: 1.0,
        }
    }

    /// Observe one EAT value; returns the de-biased variance V'_n.
    pub fn update(&mut self, x: f64) -> f64 {
        let a = self.alpha;
        self.n += 1;
        self.bias_pow *= 1.0 - a;
        self.mean = (1.0 - a) * self.mean + a * x;
        let d = x - self.mean;
        self.var = (1.0 - a) * self.var + a * d * d;
        self.debiased_var()
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// De-biased EMA mean M'_n = M_n / (1 - (1-a)^n); +inf before any
    /// observation (mirrors [`EmaVar::debiased_var`]: a fresh monitor
    /// can never read as converged). The level-rule policies of the exit
    /// zoo threshold this, the same way Alg. 1 thresholds V'.
    pub fn debiased_mean(&self) -> f64 {
        if self.n == 0 {
            return f64::INFINITY;
        }
        self.mean / (1.0 - self.bias_pow)
    }

    /// Raw V_n (biased toward 0 early on).
    pub fn var(&self) -> f64 {
        self.var
    }

    /// V'_n = V_n / (1 - (1-a)^n); +inf before any observation so that a
    /// fresh monitor can never trigger an exit. The denominator reads the
    /// running `bias_pow` product — no `powi`, no exponent clamp.
    pub fn debiased_var(&self) -> f64 {
        if self.n == 0 {
            return f64::INFINITY;
        }
        self.var / (1.0 - self.bias_pow)
    }
}

/// The de-bias denominator 1 - (1-a)^n via `powi` with the exponent
/// clamped to `i32::MAX` — the pre-running-power formulation, kept as the
/// test oracle. The clamp was a real bugfix: a long-running monitor can
/// push `n` past `i32::MAX`, where a bare `n as i32` cast wrapped to a
/// *negative* exponent and `(1-a)^-k` blew the denominator up (or
/// negative) instead of converging to 1. The clamp is exact in f64: for
/// any alpha in (0,1) the factor underflows to 0 long before the
/// exponent approaches `i32::MAX`, so the clamped denominator is already
/// 1.0 there. The live `bias_pow` product inherits that safety by
/// construction (it underflows to exactly 0.0).
#[cfg(test)]
fn debias_denom(alpha: f64, n: u64) -> f64 {
    debug_assert!(n > 0, "de-bias is undefined before the first observation");
    let e = i32::try_from(n).unwrap_or(i32::MAX);
    1.0 - (1.0 - alpha).powi(e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    #[should_panic]
    fn rejects_bad_alpha() {
        EmaVar::new(1.5);
    }

    #[test]
    fn fresh_monitor_never_exits() {
        let m = EmaVar::new(0.2);
        assert!(m.debiased_var().is_infinite());
    }

    #[test]
    fn constant_signal_variance_goes_to_zero() {
        // the zero-init bias decays at (1-a) per step, so V' needs ~n
        // steps to fall below (1-a)^n * O(x^2) — check the realistic rate
        let mut m = EmaVar::new(0.2);
        let mut v = f64::INFINITY;
        for _ in 0..150 {
            v = m.update(3.0);
        }
        assert!(v < 1e-8, "v={v}");
        assert!((m.mean() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn noisy_signal_variance_tracks_noise() {
        let mut rng = Rng::new(0);
        let mut m = EmaVar::new(0.2);
        let mut v = 0.0;
        for _ in 0..2000 {
            v = m.update(5.0 + rng.normal());
        }
        // EMA variance of N(0,1) noise: E[V] = var * (1-a)/(2-a)... in the
        // same ballpark as 1.0; just check the right order of magnitude.
        assert!(v > 0.2 && v < 2.5, "v={v}");
    }

    #[test]
    fn debiased_mean_is_exact_after_one_observation() {
        // M1 = a*x, denominator 1-(1-a) = a, so M1' = x exactly; the raw
        // mean is still biased toward the zero init
        let mut m = EmaVar::new(0.2);
        assert!(m.debiased_mean().is_infinite(), "fresh monitor reads +inf");
        m.update(7.0);
        assert!((m.debiased_mean() - 7.0).abs() < 1e-12);
        assert!(m.mean() < 7.0);
        // and it converges to the signal level like the raw mean does
        for _ in 0..200 {
            m.update(7.0);
        }
        assert!((m.debiased_mean() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn debias_matters_early() {
        // after a single observation of x, V1' should equal (x - M1)^2 /
        // (1-(1-a)) = a(x-ax)^2/a... numerically: the de-biased value is
        // much larger than the raw one early on.
        let mut m = EmaVar::new(0.1);
        m.update(10.0);
        assert!(m.debiased_var() > m.var() * 9.0);
    }

    #[test]
    fn step_change_raises_variance_then_settles() {
        let mut m = EmaVar::new(0.2);
        for _ in 0..30 {
            m.update(4.0);
        }
        let settled = m.debiased_var();
        m.update(0.5); // regime change
        let spiked = m.debiased_var();
        assert!(spiked > settled * 50.0, "spiked={spiked} settled={settled}");
        for _ in 0..120 {
            m.update(0.5);
        }
        assert!(m.debiased_var() < 1e-6, "v={}", m.debiased_var());
    }

    #[test]
    fn debias_denominator_clamps_at_the_i32_boundary() {
        // the regression: `n as i32` wrapped to a negative exponent one
        // past i32::MAX, corrupting the denominator; the clamped version
        // is continuous across the boundary (both sides are exactly 1.0
        // in f64 — the bias factor underflowed ages ago)
        let below = debias_denom(0.2, i32::MAX as u64);
        let above = debias_denom(0.2, i32::MAX as u64 + 1);
        assert_eq!(below, 1.0);
        assert_eq!(above, 1.0);
        assert_eq!(debias_denom(0.2, u64::MAX), 1.0);
        // sanity on the small-n exact values and monotonicity
        assert!((debias_denom(0.3, 1) - 0.3).abs() < 1e-15);
        let mut prev = 0.0;
        for n in [1u64, 2, 10, 100, 10_000, 1 << 22, 1 << 40, u64::MAX] {
            let d = debias_denom(0.35, n);
            assert!(d > 0.0 && d <= 1.0, "denominator out of (0,1] at n={n}: {d}");
            assert!(d >= prev, "denominator must not decrease in n");
            prev = d;
        }
    }

    #[test]
    fn running_power_tracks_powi_denominator() {
        // one multiply per update replaces powi(n); sequential rounding
        // differs from repeated squaring by at most a few ULPs and both
        // forms converge to exactly 1.0 once the bias factor underflows
        for alpha in [0.05, 0.2, 0.5, 0.9] {
            let mut m = EmaVar::new(alpha);
            for n in 1..=5000u64 {
                m.update(1.0 + (n % 7) as f64);
                let live = 1.0 - m.bias_pow;
                let oracle = debias_denom(alpha, n);
                assert!(
                    (live - oracle).abs() <= 1e-12 * oracle,
                    "alpha={alpha} n={n}: live={live} oracle={oracle}"
                );
            }
            assert_eq!(1.0 - m.bias_pow, 1.0, "alpha={alpha}");
        }
    }

    #[test]
    fn window_scales_with_alpha() {
        // small alpha -> longer memory: after a step change the variance
        // stays elevated for longer than with a big alpha.
        let mut fast = EmaVar::new(0.4);
        let mut slow = EmaVar::new(0.05);
        for _ in 0..60 {
            fast.update(2.0);
            slow.update(2.0);
        }
        for _ in 0..8 {
            fast.update(0.0);
            slow.update(0.0);
        }
        assert!(slow.debiased_var() > fast.debiased_var());
    }
}
