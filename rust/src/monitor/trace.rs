//! Per-request trajectory records: everything the eval harness needs to
//! replay early-exit decisions offline (the paper's "simulated early
//! exiting", App. H) and to draw the figures.

use crate::util::json::Json;

/// One monitored reasoning line boundary.
#[derive(Debug, Clone)]
pub struct LinePoint {
    /// 1-based reasoning line index n.
    pub line: usize,
    /// Total reasoning tokens |R| committed so far.
    pub tokens: usize,
    /// EAT (Eq. 5) with the configured suffix, from the main model.
    pub eat: f64,
    /// EAT computed by the proxy model (black-box setting), if enabled.
    pub eat_proxy: Option<f64>,
    /// EAT without the prefix string (Eq. 12), for the App. D ablation.
    pub eat_plain: Option<f64>,
    /// Entropy after newline (Eq. 14, App. F), if recorded.
    pub eat_newline: Option<f64>,
    /// De-biased EMA variance V' after observing `eat`.
    pub vhat: f64,
    /// Analytic Pass@1: probability mass on the correct answer token under
    /// the forced-answer distribution (the exact limit of Avg@K).
    pub p_correct: f64,
    /// Sampled Pass@1(Avg@K) estimate.
    pub pass1_avgk: f64,
    /// Number of unique answers among the K rollout samples (#UA@K).
    pub unique_answers: usize,
    /// Confidence score (Eq. 16): length-normalized likelihood of a greedy
    /// 5-token rollout, if recorded.
    pub confidence: Option<f64>,
}

/// A full monitored reasoning trace for one question.
#[derive(Debug, Clone)]
pub struct Trace {
    pub question_id: usize,
    /// Question difficulty (operand count n).
    pub n_ops: usize,
    /// True answer value, None when the question is corrupted/unsolvable.
    pub answer: Option<u32>,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Whether the model emitted `</think>` by itself before the budget.
    pub self_terminated: bool,
    /// All reasoning tokens that were generated (for replaying).
    pub reasoning_tokens: Vec<u32>,
    pub points: Vec<LinePoint>,
}

impl Trace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("question_id", Json::num(self.question_id as f64)),
            ("n_ops", Json::num(self.n_ops as f64)),
            (
                "answer",
                self.answer.map_or(Json::Null, |a| Json::num(a as f64)),
            ),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("self_terminated", Json::Bool(self.self_terminated)),
            (
                "reasoning_tokens",
                Json::arr(
                    self.reasoning_tokens
                        .iter()
                        .map(|&t| Json::num(t as f64)),
                ),
            ),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("line", Json::num(p.line as f64)),
                        ("tokens", Json::num(p.tokens as f64)),
                        ("eat", Json::num(p.eat)),
                        (
                            "eat_proxy",
                            p.eat_proxy.map_or(Json::Null, Json::num),
                        ),
                        (
                            "eat_plain",
                            p.eat_plain.map_or(Json::Null, Json::num),
                        ),
                        (
                            "eat_newline",
                            p.eat_newline.map_or(Json::Null, Json::num),
                        ),
                        ("vhat", Json::num(if p.vhat.is_finite() {
                            p.vhat
                        } else {
                            -1.0
                        })),
                        ("p_correct", Json::num(p.p_correct)),
                        ("pass1_avgk", Json::num(p.pass1_avgk)),
                        (
                            "unique_answers",
                            Json::num(p.unique_answers as f64),
                        ),
                        (
                            "confidence",
                            p.confidence.map_or(Json::Null, Json::num),
                        ),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Trace> {
        let points = v
            .req("points")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|p| {
                let vhat = p.get("vhat").as_f64().unwrap_or(-1.0);
                Ok(LinePoint {
                    line: p.req_usize("line")?,
                    tokens: p.req_usize("tokens")?,
                    eat: p.req("eat")?.as_f64().unwrap_or(0.0),
                    eat_proxy: p.get("eat_proxy").as_f64(),
                    eat_plain: p.get("eat_plain").as_f64(),
                    eat_newline: p.get("eat_newline").as_f64(),
                    vhat: if vhat < 0.0 { f64::INFINITY } else { vhat },
                    p_correct: p.req("p_correct")?.as_f64().unwrap_or(0.0),
                    pass1_avgk: p.req("pass1_avgk")?.as_f64().unwrap_or(0.0),
                    unique_answers: p.req_usize("unique_answers")?,
                    confidence: p.get("confidence").as_f64(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Trace {
            question_id: v.req_usize("question_id")?,
            n_ops: v.req_usize("n_ops")?,
            answer: v.get("answer").as_f64().map(|a| a as u32),
            prompt_tokens: v.req_usize("prompt_tokens")?,
            self_terminated: v.get("self_terminated").as_bool().unwrap_or(false),
            reasoning_tokens: v
                .get("reasoning_tokens")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|t| t.as_f64().map(|x| x as u32))
                .collect(),
            points,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        Trace {
            question_id: 7,
            n_ops: 4,
            answer: Some(13),
            prompt_tokens: 8,
            self_terminated: true,
            reasoning_tokens: vec![16, 17, 5, 18, 19, 5],
            points: vec![LinePoint {
                line: 1,
                tokens: 3,
                eat: 3.2,
                eat_proxy: Some(3.0),
                eat_plain: None,
                eat_newline: Some(1.1),
                vhat: f64::INFINITY,
                p_correct: 0.05,
                pass1_avgk: 0.06,
                unique_answers: 21,
                confidence: Some(0.4),
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_trace();
        let js = t.to_json();
        let back = Trace::from_json(&js).unwrap();
        assert_eq!(back.question_id, 7);
        assert_eq!(back.answer, Some(13));
        assert_eq!(back.reasoning_tokens, t.reasoning_tokens);
        assert_eq!(back.points.len(), 1);
        let p = &back.points[0];
        assert!(p.vhat.is_infinite());
        assert_eq!(p.eat_proxy, Some(3.0));
        assert_eq!(p.eat_plain, None);
        assert_eq!(p.unique_answers, 21);
    }

    #[test]
    fn unsolvable_answer_roundtrips_as_null() {
        let mut t = sample_trace();
        t.answer = None;
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.answer, None);
    }
}
