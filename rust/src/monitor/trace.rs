//! Per-request trajectory records: everything the eval harness needs to
//! replay early-exit decisions offline (the paper's "simulated early
//! exiting", App. H) and to draw the figures.

use crate::util::json::{Json, JsonScanner};

/// One monitored reasoning line boundary.
#[derive(Debug, Clone)]
pub struct LinePoint {
    /// 1-based reasoning line index n.
    pub line: usize,
    /// Total reasoning tokens |R| committed so far.
    pub tokens: usize,
    /// EAT (Eq. 5) with the configured suffix, from the main model.
    pub eat: f64,
    /// EAT computed by the proxy model (black-box setting), if enabled.
    pub eat_proxy: Option<f64>,
    /// EAT without the prefix string (Eq. 12), for the App. D ablation.
    pub eat_plain: Option<f64>,
    /// Entropy after newline (Eq. 14, App. F), if recorded.
    pub eat_newline: Option<f64>,
    /// De-biased EMA variance V' after observing `eat`.
    pub vhat: f64,
    /// Analytic Pass@1: probability mass on the correct answer token under
    /// the forced-answer distribution (the exact limit of Avg@K).
    pub p_correct: f64,
    /// Sampled Pass@1(Avg@K) estimate.
    pub pass1_avgk: f64,
    /// Number of unique answers among the K rollout samples (#UA@K).
    pub unique_answers: usize,
    /// Confidence score (Eq. 16): length-normalized likelihood of a greedy
    /// 5-token rollout, if recorded.
    pub confidence: Option<f64>,
}

/// A full monitored reasoning trace for one question.
#[derive(Debug, Clone)]
pub struct Trace {
    pub question_id: usize,
    /// Question difficulty (operand count n).
    pub n_ops: usize,
    /// True answer value, None when the question is corrupted/unsolvable.
    pub answer: Option<u32>,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Whether the model emitted `</think>` by itself before the budget.
    pub self_terminated: bool,
    /// All reasoning tokens that were generated (for replaying).
    pub reasoning_tokens: Vec<u32>,
    pub points: Vec<LinePoint>,
}

impl Trace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("question_id", Json::num(self.question_id as f64)),
            ("n_ops", Json::num(self.n_ops as f64)),
            (
                "answer",
                self.answer.map_or(Json::Null, |a| Json::num(a as f64)),
            ),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("self_terminated", Json::Bool(self.self_terminated)),
            (
                "reasoning_tokens",
                Json::arr(
                    self.reasoning_tokens
                        .iter()
                        .map(|&t| Json::num(t as f64)),
                ),
            ),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("line", Json::num(p.line as f64)),
                        ("tokens", Json::num(p.tokens as f64)),
                        ("eat", Json::num(p.eat)),
                        (
                            "eat_proxy",
                            p.eat_proxy.map_or(Json::Null, Json::num),
                        ),
                        (
                            "eat_plain",
                            p.eat_plain.map_or(Json::Null, Json::num),
                        ),
                        (
                            "eat_newline",
                            p.eat_newline.map_or(Json::Null, Json::num),
                        ),
                        ("vhat", Json::num(if p.vhat.is_finite() {
                            p.vhat
                        } else {
                            -1.0
                        })),
                        ("p_correct", Json::num(p.p_correct)),
                        ("pass1_avgk", Json::num(p.pass1_avgk)),
                        (
                            "unique_answers",
                            Json::num(p.unique_answers as f64),
                        ),
                        (
                            "confidence",
                            p.confidence.map_or(Json::Null, Json::num),
                        ),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Trace> {
        let points = v
            .req("points")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|p| {
                let vhat = p.get("vhat").as_f64().unwrap_or(-1.0);
                Ok(LinePoint {
                    line: p.req_usize("line")?,
                    tokens: p.req_usize("tokens")?,
                    eat: p.req("eat")?.as_f64().unwrap_or(0.0),
                    eat_proxy: p.get("eat_proxy").as_f64(),
                    eat_plain: p.get("eat_plain").as_f64(),
                    eat_newline: p.get("eat_newline").as_f64(),
                    vhat: if vhat < 0.0 { f64::INFINITY } else { vhat },
                    p_correct: p.req("p_correct")?.as_f64().unwrap_or(0.0),
                    pass1_avgk: p.req("pass1_avgk")?.as_f64().unwrap_or(0.0),
                    unique_answers: p.req_usize("unique_answers")?,
                    confidence: p.get("confidence").as_f64(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Trace {
            question_id: v.req_usize("question_id")?,
            n_ops: v.req_usize("n_ops")?,
            answer: v.get("answer").as_f64().map(|a| a as u32),
            prompt_tokens: v.req_usize("prompt_tokens")?,
            self_terminated: v.get("self_terminated").as_bool().unwrap_or(false),
            reasoning_tokens: v
                .get("reasoning_tokens")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|t| t.as_f64().map(|x| x as u32))
                .collect(),
            points,
        })
    }

    /// Lazy-scan twin of [`Trace::from_json`]: decodes a trace straight
    /// from JSON text in one forward pass per object, never materializing
    /// a `Json` tree (DESIGN.md §3.8). Field semantics match `from_json`
    /// exactly — pinned by `scanner_load_matches_tree_load` here and the
    /// differential proptest in `tests/proptests.rs`.
    pub fn from_scanner(v: &JsonScanner) -> anyhow::Result<Trace> {
        use anyhow::Context;
        let mut question_id = None;
        let mut n_ops = None;
        let mut answer = None;
        let mut prompt_tokens = None;
        let mut self_terminated = false;
        let mut reasoning_tokens = Vec::new();
        let mut points = None;
        for (key, val) in v.entries() {
            match key.as_ref() {
                "question_id" => question_id = val.path_usize(&[]),
                "n_ops" => n_ops = val.path_usize(&[]),
                "answer" => answer = val.path_num(&[]).map(|a| a as u32),
                "prompt_tokens" => prompt_tokens = val.path_usize(&[]),
                "self_terminated" => {
                    self_terminated = val.path_bool(&[]).unwrap_or(false)
                }
                "reasoning_tokens" => {
                    reasoning_tokens = val
                        .array_items()
                        .filter_map(|t| t.path_num(&[]).map(|x| x as u32))
                        .collect()
                }
                "points" => {
                    points = Some(
                        val.array_items()
                            .map(|p| LinePoint::from_scanner(&p))
                            .collect::<anyhow::Result<Vec<_>>>()?,
                    )
                }
                _ => {}
            }
        }
        Ok(Trace {
            question_id: question_id
                .context("JSON key `question_id` not a usize")?,
            n_ops: n_ops.context("JSON key `n_ops` not a usize")?,
            answer,
            prompt_tokens: prompt_tokens
                .context("JSON key `prompt_tokens` not a usize")?,
            self_terminated,
            reasoning_tokens,
            points: points.context("missing JSON key `points`")?,
        })
    }
}

impl LinePoint {
    fn from_scanner(p: &JsonScanner) -> anyhow::Result<LinePoint> {
        use anyhow::Context;
        let mut line = None;
        let mut tokens = None;
        // `Some(..)` records key presence: `from_json` requires the key
        // but decays a non-numeric value to 0.0.
        let mut eat = None;
        let mut eat_proxy = None;
        let mut eat_plain = None;
        let mut eat_newline = None;
        let mut vhat = None;
        let mut p_correct = None;
        let mut pass1_avgk = None;
        let mut unique_answers = None;
        let mut confidence = None;
        for (key, val) in p.entries() {
            match key.as_ref() {
                "line" => line = val.path_usize(&[]),
                "tokens" => tokens = val.path_usize(&[]),
                "eat" => eat = Some(val.path_num(&[]).unwrap_or(0.0)),
                "eat_proxy" => eat_proxy = val.path_num(&[]),
                "eat_plain" => eat_plain = val.path_num(&[]),
                "eat_newline" => eat_newline = val.path_num(&[]),
                "vhat" => vhat = val.path_num(&[]),
                "p_correct" => {
                    p_correct = Some(val.path_num(&[]).unwrap_or(0.0))
                }
                "pass1_avgk" => {
                    pass1_avgk = Some(val.path_num(&[]).unwrap_or(0.0))
                }
                "unique_answers" => unique_answers = val.path_usize(&[]),
                "confidence" => confidence = val.path_num(&[]),
                _ => {}
            }
        }
        let vhat = vhat.unwrap_or(-1.0);
        Ok(LinePoint {
            line: line.context("JSON key `line` not a usize")?,
            tokens: tokens.context("JSON key `tokens` not a usize")?,
            eat: eat.context("missing JSON key `eat`")?,
            eat_proxy,
            eat_plain,
            eat_newline,
            vhat: if vhat < 0.0 { f64::INFINITY } else { vhat },
            p_correct: p_correct.context("missing JSON key `p_correct`")?,
            pass1_avgk: pass1_avgk
                .context("missing JSON key `pass1_avgk`")?,
            unique_answers: unique_answers
                .context("JSON key `unique_answers` not a usize")?,
            confidence,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        Trace {
            question_id: 7,
            n_ops: 4,
            answer: Some(13),
            prompt_tokens: 8,
            self_terminated: true,
            reasoning_tokens: vec![16, 17, 5, 18, 19, 5],
            points: vec![LinePoint {
                line: 1,
                tokens: 3,
                eat: 3.2,
                eat_proxy: Some(3.0),
                eat_plain: None,
                eat_newline: Some(1.1),
                vhat: f64::INFINITY,
                p_correct: 0.05,
                pass1_avgk: 0.06,
                unique_answers: 21,
                confidence: Some(0.4),
            }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_trace();
        let js = t.to_json();
        let back = Trace::from_json(&js).unwrap();
        assert_eq!(back.question_id, 7);
        assert_eq!(back.answer, Some(13));
        assert_eq!(back.reasoning_tokens, t.reasoning_tokens);
        assert_eq!(back.points.len(), 1);
        let p = &back.points[0];
        assert!(p.vhat.is_infinite());
        assert_eq!(p.eat_proxy, Some(3.0));
        assert_eq!(p.eat_plain, None);
        assert_eq!(p.unique_answers, 21);
    }

    #[test]
    fn scanner_load_matches_tree_load() {
        let mut t = sample_trace();
        t.points.push(LinePoint {
            line: 2,
            tokens: 6,
            eat: 0.125,
            eat_proxy: None,
            eat_plain: Some(-0.5),
            eat_newline: None,
            vhat: 0.25,
            p_correct: 0.5,
            pass1_avgk: 0.75,
            unique_answers: 3,
            confidence: None,
        });
        let text = t.to_json().to_string();
        let tree = Trace::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        let scan = Trace::from_scanner(&JsonScanner::new(&text)).unwrap();
        assert_eq!(scan.question_id, tree.question_id);
        assert_eq!(scan.n_ops, tree.n_ops);
        assert_eq!(scan.answer, tree.answer);
        assert_eq!(scan.prompt_tokens, tree.prompt_tokens);
        assert_eq!(scan.self_terminated, tree.self_terminated);
        assert_eq!(scan.reasoning_tokens, tree.reasoning_tokens);
        assert_eq!(scan.points.len(), tree.points.len());
        for (a, b) in scan.points.iter().zip(tree.points.iter()) {
            assert_eq!(a.line, b.line);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.eat.to_bits(), b.eat.to_bits());
            assert_eq!(a.eat_proxy, b.eat_proxy);
            assert_eq!(a.eat_plain, b.eat_plain);
            assert_eq!(a.eat_newline, b.eat_newline);
            assert_eq!(a.vhat.to_bits(), b.vhat.to_bits());
            assert_eq!(a.p_correct.to_bits(), b.p_correct.to_bits());
            assert_eq!(a.pass1_avgk.to_bits(), b.pass1_avgk.to_bits());
            assert_eq!(a.unique_answers, b.unique_answers);
            assert_eq!(a.confidence, b.confidence);
        }
    }

    #[test]
    fn scanner_load_requires_points() {
        let err = Trace::from_scanner(&JsonScanner::new("{\"question_id\":1}"))
            .unwrap_err();
        assert!(err.to_string().contains("points"), "{err}");
    }

    #[test]
    fn unsolvable_answer_roundtrips_as_null() {
        let mut t = sample_trace();
        t.answer = None;
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.answer, None);
    }
}
