//! Offline API stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The serving stack's PJRT layer (`eat-serve` feature `pjrt`) is written
//! against the small slice of the xla-rs surface below. This stub lets the
//! crate *compile and link* in environments that do not carry the real
//! `xla_extension` C++ toolchain: every entry point that would touch PJRT
//! returns [`Error::Stub`], so `Runtime::load` fails cleanly and all
//! artifact-dependent tests, benches and CLI paths skip with a message.
//!
//! To execute the AOT artifacts for real, point the `xla` dependency in
//! the workspace `Cargo.toml` at an xla-rs checkout instead of this path
//! (see DESIGN.md §2); no eat-serve source change is needed.

use std::fmt;

/// The single error this stub can produce.
#[derive(Debug)]
pub enum Error {
    /// Raised by every PJRT entry point of the stub.
    Stub,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: built against rust/xla-stub, not a real xla_extension \
             (swap the `xla` path dependency for an xla-rs checkout to run \
             AOT artifacts)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Stub)
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Stub)
    }
}

/// Parsed HLO module (stub: cannot be constructed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Stub)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub: cannot be constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

/// Device buffer handle (stub: cannot be constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

/// Host literal (stub: cannot be constructed).
pub struct Literal;

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Stub)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::Stub)
    }
}
